//! Future-event-queue implementations behind the [`EventQueue`] trait.
//!
//! The seed engine used a plain `BinaryHeap<Reverse<SimEvent>>`; at
//! megascale the heap's `O(log n)` pops and its inability to cancel
//! re-armed timers dominate the DES hot loop (the event-list bottleneck
//! D'Angelo & Marzolla identify for parallel DES). Two implementations are
//! selectable per run and cross-checkable against each other:
//!
//! * [`BinaryHeapQueue`] — the seed structure, kept as the reference.
//! * [`CalendarQueue`] — an indexed two-tier queue: a ring of near-future
//!   buckets (sorted lazily, popped from the cheap end) plus a far-future
//!   overflow list that re-anchors the ring whenever the near window
//!   drains. Amortized `O(1)` push/pop when event times are spread, and
//!   worst-case it degrades to one sorted bucket — never worse than a
//!   sorted vector.
//!
//! Both support **lazy cancellation**: [`EventQueue::cancel`] tombstones a
//! scheduled event by its handle (the engine's sequence number), and `pop`
//! silently skips tombstones, so a cancelled event is *never dispatched*
//! and never counted. This is what lets the next-completion scheduler
//! re-arm one wake-up per VM instead of dispatching stale version-guarded
//! timers.
//!
//! Contract shared by all implementations: `pop` returns events in strict
//! `(time, seq)` order (FIFO at equal timestamps), and `cancel` must only
//! be called with the handle of a scheduled, not-yet-delivered event.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use crate::sim::event::SimEvent;

/// Opaque handle to a scheduled event (the engine's sequence number).
pub type EventHandle = u64;

/// A future event queue: the pluggable core of the DES hot path.
pub trait EventQueue {
    /// Insert an event. The event's `seq` doubles as its cancel handle.
    fn push(&mut self, ev: SimEvent);
    /// Remove and return the earliest live event in `(time, seq)` order.
    fn pop(&mut self) -> Option<SimEvent>;
    /// Tombstone a scheduled, not-yet-delivered event; it will never be
    /// returned by `pop`. Returns `false` if the handle was already
    /// tombstoned.
    fn cancel(&mut self, handle: EventHandle) -> bool;
    /// Live (non-tombstoned) events currently queued.
    fn len(&self) -> usize;
    /// True when no live event is queued.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Which [`EventQueue`] implementation a simulation runs on
/// (`eventQueue` in `cloud2sim.properties`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// The seed `BinaryHeap` reference queue.
    Heap,
    /// The indexed two-tier calendar queue (default).
    Indexed,
}

/// Construct the queue implementation for a [`QueueKind`].
pub fn make_queue(kind: QueueKind) -> Box<dyn EventQueue> {
    match kind {
        QueueKind::Heap => Box::new(BinaryHeapQueue::new()),
        QueueKind::Indexed => Box::new(CalendarQueue::new()),
    }
}

/// The seed event queue: a binary min-heap plus lazy tombstones.
pub struct BinaryHeapQueue {
    heap: BinaryHeap<Reverse<SimEvent>>,
    cancelled: HashSet<EventHandle>,
}

impl BinaryHeapQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            cancelled: HashSet::new(),
        }
    }
}

impl Default for BinaryHeapQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue for BinaryHeapQueue {
    fn push(&mut self, ev: SimEvent) {
        self.heap.push(Reverse(ev));
    }

    fn pop(&mut self) -> Option<SimEvent> {
        while let Some(Reverse(ev)) = self.heap.pop() {
            if self.cancelled.remove(&ev.seq) {
                continue; // tombstone: skipped, never dispatched
            }
            return Some(ev);
        }
        None
    }

    fn cancel(&mut self, handle: EventHandle) -> bool {
        self.cancelled.insert(handle)
    }

    fn len(&self) -> usize {
        self.heap.len() - self.cancelled.len()
    }
}

/// Ring size of the calendar queue's near-future tier. 256 buckets keeps
/// the ring-scan bounded while bucket occupancy stays small for the
/// event-time spreads the cloud scenarios produce.
const CALENDAR_BUCKETS: usize = 256;

/// The indexed two-tier event queue (calendar/ladder-queue style).
///
/// Near-future events live in a ring of `CALENDAR_BUCKETS` buckets of
/// `width` virtual seconds each, starting at `ring_start`; far-future
/// events wait in `overflow`. The bucket under the read cursor is sorted
/// lazily (descending, so pops are `Vec::pop` from the cheap end) the
/// first time it is read; pushes landing in the current bucket insert at
/// their sorted position, pushes into later buckets are plain appends.
/// When the ring drains, the queue re-anchors: the ring window and bucket
/// width are recomputed from the overflow's time span, which keeps the
/// structure adaptive without any tuning knobs.
pub struct CalendarQueue {
    buckets: Vec<Vec<SimEvent>>,
    /// Bucket width in virtual seconds (re-fit at every re-anchor).
    width: f64,
    /// Virtual time of bucket 0's left edge.
    ring_start: f64,
    /// Read cursor: index of the bucket currently being drained.
    cur: usize,
    /// Whether `buckets[cur]` is sorted (descending) already.
    cur_sorted: bool,
    /// Events beyond the ring window, unsorted.
    overflow: Vec<SimEvent>,
    /// Stored events, tombstoned ones included.
    count: usize,
    cancelled: HashSet<EventHandle>,
}

impl CalendarQueue {
    /// Empty queue.
    pub fn new() -> Self {
        Self {
            buckets: (0..CALENDAR_BUCKETS).map(|_| Vec::new()).collect(),
            width: 1.0,
            ring_start: 0.0,
            cur: 0,
            cur_sorted: false,
            overflow: Vec::new(),
            count: 0,
            cancelled: HashSet::new(),
        }
    }

    /// Re-anchor the (fully drained) ring over the overflow's time span
    /// and move every pending event into its bucket.
    fn migrate(&mut self) {
        debug_assert!(self.buckets.iter().all(Vec::is_empty));
        debug_assert!(!self.overflow.is_empty());
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for ev in &self.overflow {
            lo = lo.min(ev.time);
            hi = hi.max(ev.time);
        }
        let nb = self.buckets.len();
        let span = hi - lo;
        // fit the whole span into the ring: hi must land in the last
        // bucket, so divide by nb - 1 (with a floor against denormals)
        self.width = if span > 0.0 {
            (span / (nb - 1) as f64).max(1e-12)
        } else {
            1.0
        };
        self.ring_start = lo;
        self.cur = 0;
        self.cur_sorted = false;
        let mut ring_end = self.ring_start + self.width * nb as f64;
        if ring_end <= lo {
            // at extreme magnitudes lo + width*nb can round back to lo
            // (ULP(lo) > the whole window); degrade to plain sorted
            // buckets instead of bouncing every event back to the
            // overflow forever
            ring_end = f64::INFINITY;
        }
        let pending = std::mem::take(&mut self.overflow);
        for ev in pending {
            if ev.time < ring_end {
                let idx = (((ev.time - self.ring_start) / self.width) as usize).min(nb - 1);
                self.buckets[idx].push(ev);
            } else {
                self.overflow.push(ev);
            }
        }
    }
}

impl Default for CalendarQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue for CalendarQueue {
    fn push(&mut self, ev: SimEvent) {
        self.count += 1;
        if self.count == 1 {
            // empty queue: re-anchor the ring at this event
            self.ring_start = ev.time;
            self.cur = 0;
            self.cur_sorted = false;
            self.buckets[0].push(ev);
            return;
        }
        let nb = self.buckets.len();
        let ring_end = self.ring_start + self.width * nb as f64;
        if ev.time < ring_end {
            // clamp against float edges and the read cursor: an event at
            // the current virtual time must stay reachable (the cast
            // saturates, so pre-window times land at the cursor)
            let idx = (((ev.time - self.ring_start) / self.width) as usize).clamp(self.cur, nb - 1);
            if idx == self.cur && self.cur_sorted {
                // current bucket is mid-drain and sorted descending:
                // insert at position so FIFO (time, seq) order holds
                let pos = self.buckets[idx].partition_point(|e| *e > ev);
                self.buckets[idx].insert(pos, ev);
            } else {
                self.buckets[idx].push(ev);
            }
        } else {
            self.overflow.push(ev);
        }
    }

    fn pop(&mut self) -> Option<SimEvent> {
        loop {
            if self.count == 0 {
                return None;
            }
            while self.cur < self.buckets.len() && self.buckets[self.cur].is_empty() {
                self.cur += 1;
                self.cur_sorted = false;
            }
            if self.cur == self.buckets.len() {
                // ring drained; everything left is in the overflow
                self.migrate();
                continue;
            }
            if !self.cur_sorted {
                // descending, so the earliest (time, seq) pops from the end
                self.buckets[self.cur].sort();
                self.buckets[self.cur].reverse();
                self.cur_sorted = true;
            }
            let ev = self.buckets[self.cur].pop().expect("non-empty bucket");
            self.count -= 1;
            if self.cancelled.remove(&ev.seq) {
                continue; // tombstone: skipped, never dispatched
            }
            return Some(ev);
        }
    }

    fn cancel(&mut self, handle: EventHandle) -> bool {
        self.cancelled.insert(handle)
    }

    fn len(&self) -> usize {
        self.count - self.cancelled.len()
    }
}

/// Free-list pool of `Vec<T>` payload buffers.
///
/// The megascale submission path moves one batch buffer per
/// broker→datacenter event; without pooling that is one heap allocation
/// per window per datacenter for the entire run. The pool recycles drained
/// buffers (`clear()` keeps capacity), so steady-state submission
/// allocates only until the in-flight high-water mark is reached.
pub struct EventPool<T> {
    free: Vec<Vec<T>>,
    allocated: u64,
    reused: u64,
}

impl<T> EventPool<T> {
    /// Empty pool.
    pub fn new() -> Self {
        Self {
            free: Vec::new(),
            allocated: 0,
            reused: 0,
        }
    }

    /// Take an empty buffer — recycled if one is free, fresh otherwise.
    pub fn acquire(&mut self) -> Vec<T> {
        match self.free.pop() {
            Some(buf) => {
                self.reused += 1;
                buf
            }
            None => {
                self.allocated += 1;
                Vec::new()
            }
        }
    }

    /// Return a drained buffer to the free list (contents are dropped,
    /// capacity is kept).
    pub fn recycle(&mut self, mut buf: Vec<T>) {
        buf.clear();
        self.free.push(buf);
    }

    /// Buffers ever freshly allocated (the pool's high-water mark).
    pub fn allocated(&self) -> u64 {
        self.allocated
    }

    /// Buffers served from the free list.
    pub fn reused(&self) -> u64 {
        self.reused
    }
}

impl<T> Default for EventPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::{EventData, EventTag};

    fn ev(time: f64, seq: u64) -> SimEvent {
        SimEvent {
            time,
            seq,
            src: 0,
            dst: 0,
            tag: EventTag::Start,
            data: EventData::None,
        }
    }

    fn drain(q: &mut dyn EventQueue) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.time, e.seq));
        }
        out
    }

    #[test]
    fn both_queues_pop_in_time_seq_order() {
        for kind in [QueueKind::Heap, QueueKind::Indexed] {
            let mut q = make_queue(kind);
            // same-timestamp FIFO batch + spread times, pushed out of order
            for (t, s) in [(5.0, 0), (1.0, 1), (5.0, 2), (0.5, 3), (1.0, 4)] {
                q.push(ev(t, s));
            }
            assert_eq!(q.len(), 5);
            assert_eq!(
                drain(q.as_mut()),
                vec![(0.5, 3), (1.0, 1), (1.0, 4), (5.0, 0), (5.0, 2)],
                "{kind:?}"
            );
            assert!(q.is_empty());
        }
    }

    #[test]
    fn cancelled_events_never_pop() {
        for kind in [QueueKind::Heap, QueueKind::Indexed] {
            let mut q = make_queue(kind);
            for (t, s) in [(1.0, 0), (2.0, 1), (3.0, 2)] {
                q.push(ev(t, s));
            }
            assert!(q.cancel(1));
            assert!(!q.cancel(1), "double cancel reports false");
            assert_eq!(q.len(), 2, "{kind:?}");
            assert_eq!(drain(q.as_mut()), vec![(1.0, 0), (3.0, 2)], "{kind:?}");
        }
    }

    #[test]
    fn calendar_far_future_overflow_migrates() {
        let mut q = CalendarQueue::new();
        // near cluster then a far-future cluster well past the ring
        q.push(ev(0.0, 0));
        for s in 1..50 {
            q.push(ev(1_000_000.0 + s as f64, s));
        }
        let popped = drain(&mut q);
        assert_eq!(popped.len(), 50);
        assert!(popped.windows(2).all(|w| w[0] <= w[1]), "{popped:?}");
    }

    #[test]
    fn calendar_survives_ulp_scale_timestamps() {
        // at t ~ 2^62 the ULP (1024) exceeds the ring window (256 * width),
        // so ring_start + width * nb rounds back to ring_start; migrate
        // must degrade to a sorted bucket, not loop forever
        let big = 4.7e18;
        let mut q = CalendarQueue::new();
        q.push(ev(big, 0));
        q.push(ev(big + 2048.0, 1));
        q.push(ev(big + 1024.0, 2));
        assert_eq!(
            drain(&mut q),
            vec![(big, 0), (big + 1024.0, 2), (big + 2048.0, 1)]
        );
    }

    #[test]
    fn calendar_reanchors_after_drain() {
        let mut q = CalendarQueue::new();
        q.push(ev(1.0, 0));
        assert_eq!(q.pop().map(|e| e.seq), Some(0));
        assert!(q.pop().is_none());
        // empty again: a push far from the old window must still work
        q.push(ev(9.0e9, 1));
        q.push(ev(9.0e9, 2));
        assert_eq!(drain(&mut q), vec![(9.0e9, 1), (9.0e9, 2)]);
    }

    #[test]
    fn push_into_current_sorted_bucket_keeps_order() {
        let mut q = CalendarQueue::new();
        for s in 0..4 {
            q.push(ev(0.25 * s as f64, s));
        }
        // drain one so the current bucket is sorted mid-read, then push a
        // zero-delay event at the current time with a later seq
        let first = q.pop().unwrap();
        assert_eq!(first.seq, 0);
        q.push(ev(first.time, 10));
        let rest = drain(&mut q);
        assert_eq!(rest, vec![(0.0, 10), (0.25, 1), (0.5, 2), (0.75, 3)]);
    }

    #[test]
    fn event_pool_recycles_capacity() {
        let mut pool: EventPool<u64> = EventPool::new();
        let mut a = pool.acquire();
        a.extend(0..100);
        let cap = a.capacity();
        pool.recycle(a);
        let b = pool.acquire();
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert!(b.capacity() >= cap, "recycled buffers keep their capacity");
        assert_eq!(pool.allocated(), 1);
        assert_eq!(pool.reused(), 1);
    }

    #[test]
    fn event_pool_high_water_mark_is_concurrent_demand() {
        let mut pool: EventPool<u8> = EventPool::new();
        // three buffers live at once, then serial acquire/recycle cycles
        let (a, b, c) = (pool.acquire(), pool.acquire(), pool.acquire());
        pool.recycle(a);
        pool.recycle(b);
        pool.recycle(c);
        for _ in 0..10 {
            let x = pool.acquire();
            pool.recycle(x);
        }
        assert_eq!(pool.allocated(), 3, "steady state allocates nothing new");
        assert_eq!(pool.reused(), 10);
    }
}
