//! Struct-of-arrays cloudlet storage: the memory-lean core of the
//! million-cloudlet multi-tenant scenarios.
//!
//! The seed pipeline moved whole boxed [`Cloudlet`] structs broker →
//! datacenter → broker and retained every finished cloudlet, so peak heap
//! scaled with *submitted* work. [`CloudletStore`] replaces that ownership
//! shuffle with one arena keyed by a dense [`CloudletId`]:
//!
//! * **Retained mode** keeps parallel `Vec`s (length / tenant / VM binding /
//!   status / timestamps) and can [`CloudletStore::materialize`] the exact
//!   `Vec<Cloudlet>` the seed path produced — bit-for-bit, including the
//!   per-cloudlet submit/start/finish instants.
//! * **Streaming mode** keeps *nothing* per cloudlet: only fixed-size
//!   per-tenant digests and per-`(tenant, vm)` accumulators survive, so peak
//!   heap scales with **active** VMs and in-flight windows, not with the
//!   number of cloudlets ever submitted.
//!
//! Both modes update the same streaming aggregates, which is what lets the
//! property tests assert retained-vs-streaming equivalence and lets the
//! `megascale_multitenant` referee compare a combined multi-tenant run
//! against its single-tenant decomposition bit-for-bit:
//!
//! * per-`(tenant, vm)` turnaround sums accumulate in per-VM completion
//!   order (invariant across tenant interleavings, because one VM only ever
//!   serves one tenant's cloudlets) and fold in `BTreeMap` key order at
//!   report time — so the mean is a bit-deterministic f64;
//! * latency quantiles come from a fixed 256-bucket log₁₀ histogram whose
//!   u64 bucket counts commute — order-insensitive by construction.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::sim::cloudlet::{Cloudlet, CloudletStatus};
use crate::sim::event::SubmitEntry;
use crate::sim::queue::EventPool;

/// Dense arena index of a registered cloudlet (the broker→datacenter
/// hand-off currency; display ids resolve only at report time).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CloudletId(pub u32);

/// Tenant identity: which broker's workload a cloudlet belongs to.
pub type TenantId = u32;

/// Sentinel for "not bound to any VM".
const NO_VM: u32 = u32::MAX;

/// What the store keeps per registered cloudlet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetentionMode {
    /// Full per-cloudlet SoA arrays; [`CloudletStore::materialize`] works.
    Retained,
    /// Streaming digests only — O(tenants + VMs) state, O(1) per cloudlet.
    Streaming,
}

/// Modeled bytes per registered cloudlet in [`RetentionMode::Retained`]
/// (the SoA rows: ids, length, binding, status, three timestamps).
pub const RETAINED_BYTES_PER_CLOUDLET: u64 = 56;

/// Modeled bytes per *in-flight* cloudlet (scheduler entry + submit-batch
/// slot) — the term that dominates streaming-mode peak heap.
pub const ACTIVE_ENTRY_BYTES: u64 = 48;

/// Histogram resolution of the per-tenant turnaround digest.
pub const DIGEST_BUCKETS: usize = 256;
const DIGEST_LOG10_LO: f64 = -6.0;
const DIGEST_LOG10_SPAN: f64 = 12.0;

/// Per-`(tenant, vm)` streaming accumulator.
#[derive(Debug, Clone, Copy, Default)]
struct VmAgg {
    count: u64,
    sum_turnaround: f64,
}

/// Per-tenant counters + fixed-size latency digest.
#[derive(Debug, Clone)]
struct TenantAgg {
    registered: u64,
    completed: u64,
    failed: u64,
    rebound: u64,
    retries_exhausted: u64,
    buckets: Vec<u64>,
}

impl TenantAgg {
    fn new() -> Self {
        Self {
            registered: 0,
            completed: 0,
            failed: 0,
            rebound: 0,
            retries_exhausted: 0,
            buckets: vec![0; DIGEST_BUCKETS],
        }
    }
}

/// Digest bucket for a turnaround value (clamped log₁₀ scale over
/// `[1e-6, 1e6)` seconds).
fn bucket_of(turnaround: f64) -> usize {
    let l = turnaround.max(1e-9).log10();
    let idx = ((l - DIGEST_LOG10_LO) * (DIGEST_BUCKETS as f64 / DIGEST_LOG10_SPAN)) as isize;
    idx.clamp(0, DIGEST_BUCKETS as isize - 1) as usize
}

/// Lower edge (seconds) of a digest bucket — what quantile queries report.
fn bucket_edge(idx: usize) -> f64 {
    10f64.powf(DIGEST_LOG10_LO + idx as f64 * DIGEST_LOG10_SPAN / DIGEST_BUCKETS as f64)
}

/// Smallest bucket edge at or above the `q`-quantile of the digest.
fn digest_quantile(buckets: &[u64], count: u64, q: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = ((q * count as f64).ceil()).max(1.0) as u64;
    let mut seen = 0u64;
    for (idx, &b) in buckets.iter().enumerate() {
        seen += b;
        if seen >= target {
            return bucket_edge(idx);
        }
    }
    bucket_edge(DIGEST_BUCKETS - 1)
}

/// Report-time view of one tenant's streaming stats.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant id.
    pub tenant: TenantId,
    /// Cloudlets registered for this tenant (submitted workload).
    pub registered: u64,
    /// Cloudlets completed successfully.
    pub completed: u64,
    /// Cloudlets failed (at bind, at dispatch, or after the crash-retry
    /// budget ran out — retries-exhausted cloudlets count here too).
    pub failed: u64,
    /// Crash-failed cloudlets re-bound to a surviving VM (a cloudlet
    /// re-bound twice counts twice).
    pub rebound: u64,
    /// Crash-failed cloudlets dropped after the retry budget ran out.
    pub retries_exhausted: u64,
    /// Exact turnaround sum, folded from per-VM accumulators in VM-id
    /// order (bit-deterministic across tenant interleavings).
    pub sum_turnaround: f64,
    /// `sum_turnaround / completed` (0 when nothing completed).
    pub mean_turnaround: f64,
    /// Digest median turnaround (bucket lower edge, seconds).
    pub p50_turnaround: f64,
    /// Digest 99th-percentile turnaround (bucket lower edge, seconds).
    pub p99_turnaround: f64,
}

/// The struct-of-arrays cloudlet arena shared by brokers and datacenters
/// (single-threaded DES ⇒ `Rc<RefCell<_>>`, see [`SharedStore`]).
pub struct CloudletStore {
    mode: RetentionMode,
    // --- retained SoA rows (empty in Streaming mode) ---
    ext_id: Vec<u32>,
    user: Vec<u32>,
    tenant: Vec<u32>,
    length_mi: Vec<u64>,
    pes: Vec<u32>,
    vm: Vec<u32>,
    status: Vec<CloudletStatus>,
    submit: Vec<f64>,
    start: Vec<f64>,
    finish: Vec<f64>,
    // --- always-on streaming aggregates ---
    vm_aggs: BTreeMap<(u32, u32), VmAgg>,
    tenants: BTreeMap<u32, TenantAgg>,
    registered: u64,
    completed: u64,
    failed: u64,
    active_now: u64,
    peak_active: u64,
    /// Free-list of submit-batch payload buffers: the broker acquires a
    /// buffer per datacenter batch, the datacenter drains it and recycles
    /// it here, so steady-state submission allocates nothing per window.
    pub pool: EventPool<SubmitEntry>,
}

/// Shared handle: one store per simulation, shared by its entities.
pub type SharedStore = Rc<RefCell<CloudletStore>>;

impl CloudletStore {
    /// Empty store in the given retention mode.
    pub fn new(mode: RetentionMode) -> Self {
        Self {
            mode,
            ext_id: Vec::new(),
            user: Vec::new(),
            tenant: Vec::new(),
            length_mi: Vec::new(),
            pes: Vec::new(),
            vm: Vec::new(),
            status: Vec::new(),
            submit: Vec::new(),
            start: Vec::new(),
            finish: Vec::new(),
            vm_aggs: BTreeMap::new(),
            tenants: BTreeMap::new(),
            registered: 0,
            completed: 0,
            failed: 0,
            active_now: 0,
            peak_active: 0,
            pool: EventPool::new(),
        }
    }

    /// Shared empty store.
    pub fn shared(mode: RetentionMode) -> SharedStore {
        Rc::new(RefCell::new(Self::new(mode)))
    }

    /// Retention mode of this store.
    pub fn mode(&self) -> RetentionMode {
        self.mode
    }

    /// Register a bound (or bind-failed) cloudlet, assigning its dense id.
    /// Captures the cloudlet's current field values; in Streaming mode only
    /// the counters move.
    pub fn register(&mut self, c: &Cloudlet, tenant: TenantId) -> CloudletId {
        assert!(self.registered < u32::MAX as u64, "cloudlet arena full");
        let id = CloudletId(self.registered as u32);
        self.registered += 1;
        self.tenants.entry(tenant).or_insert_with(TenantAgg::new).registered += 1;
        if self.mode == RetentionMode::Retained {
            self.ext_id.push(c.id as u32);
            self.user.push(c.user_id as u32);
            self.tenant.push(tenant);
            self.length_mi.push(c.length_mi);
            self.pes.push(c.pes as u32);
            self.vm.push(c.vm_id.map(|v| v as u32).unwrap_or(NO_VM));
            self.status.push(c.status);
            self.submit.push(c.submit_time);
            self.start.push(c.start_time);
            self.finish.push(c.finish_time);
        }
        id
    }

    /// Count `n` cloudlets as dispatched (in flight at a datacenter).
    pub fn mark_dispatched(&mut self, n: u64) {
        self.active_now += n;
        self.peak_active = self.peak_active.max(self.active_now);
    }

    /// Record a failure. `was_dispatched` distinguishes a datacenter-side
    /// failure (decrements the in-flight gauge) from a bind-time failure
    /// (which never entered a datacenter).
    pub fn record_fail(&mut self, id: CloudletId, tenant: TenantId, was_dispatched: bool) {
        self.failed += 1;
        self.tenants.entry(tenant).or_insert_with(TenantAgg::new).failed += 1;
        if was_dispatched {
            debug_assert!(self.active_now > 0);
            self.active_now -= 1;
        }
        if self.mode == RetentionMode::Retained {
            self.status[id.0 as usize] = CloudletStatus::Failed;
        }
    }

    /// Take `n` cloudlets off the in-flight gauge because their datacenter
    /// crashed. Not a terminal record: the broker either re-dispatches them
    /// (via [`CloudletStore::mark_dispatched`]) or fails them (via
    /// [`CloudletStore::record_fail`] with `was_dispatched = false`).
    pub fn record_crash_interrupt(&mut self, n: u64) {
        debug_assert!(self.active_now >= n, "crash interrupt exceeds in-flight");
        self.active_now -= n;
    }

    /// Count `n` crash-failed cloudlets of `tenant` as re-bound.
    pub fn record_rebound(&mut self, tenant: TenantId, n: u64) {
        self.tenants.entry(tenant).or_insert_with(TenantAgg::new).rebound += n;
    }

    /// Count `n` crash-failed cloudlets of `tenant` as dropped with their
    /// retry budget exhausted (the caller also records the terminal
    /// failure via [`CloudletStore::record_fail`]).
    pub fn record_retry_exhausted(&mut self, tenant: TenantId, n: u64) {
        self.tenants.entry(tenant).or_insert_with(TenantAgg::new).retries_exhausted += n;
    }

    /// Record a completion with the scheduler's exact virtual-time stamps.
    pub fn record_finish(
        &mut self,
        id: CloudletId,
        tenant: TenantId,
        vm: u32,
        submit: f64,
        start: f64,
        finish: f64,
    ) {
        self.completed += 1;
        debug_assert!(self.active_now > 0);
        self.active_now -= 1;
        let turnaround = finish - submit;
        let agg = self.vm_aggs.entry((tenant, vm)).or_default();
        agg.count += 1;
        agg.sum_turnaround += turnaround;
        let t = self.tenants.entry(tenant).or_insert_with(TenantAgg::new);
        t.completed += 1;
        t.buckets[bucket_of(turnaround)] += 1;
        if self.mode == RetentionMode::Retained {
            let i = id.0 as usize;
            self.status[i] = CloudletStatus::Success;
            self.vm[i] = vm;
            self.submit[i] = submit;
            self.start[i] = start;
            self.finish[i] = finish;
        }
    }

    /// Cloudlets registered so far.
    pub fn registered(&self) -> u64 {
        self.registered
    }
    /// Cloudlets completed successfully.
    pub fn completed(&self) -> u64 {
        self.completed
    }
    /// Cloudlets failed.
    pub fn failed(&self) -> u64 {
        self.failed
    }
    /// Cloudlets currently in flight.
    pub fn active_now(&self) -> u64 {
        self.active_now
    }
    /// High-water mark of in-flight cloudlets.
    pub fn peak_active(&self) -> u64 {
        self.peak_active
    }

    /// Modeled peak heap of the cloudlet pipeline: retained rows (zero in
    /// Streaming mode) + in-flight entries at their high-water mark + the
    /// fixed digest/accumulator state. This is the quantity the
    /// `megascale_multitenant` CI gate holds to a per-submitted-cloudlet
    /// byte budget.
    pub fn peak_heap_bytes(&self) -> u64 {
        let per_row = match self.mode {
            RetentionMode::Retained => RETAINED_BYTES_PER_CLOUDLET,
            RetentionMode::Streaming => 0,
        };
        self.registered * per_row
            + self.peak_active * ACTIVE_ENTRY_BYTES
            + self.tenants.len() as u64 * (DIGEST_BUCKETS as u64 * 8 + 64)
            + self.vm_aggs.len() as u64 * 32
    }

    /// Rebuild the seed-shaped `Vec<Cloudlet>` (terminal cloudlets only,
    /// sorted by display id). Retained mode only.
    pub fn materialize(&self) -> Vec<Cloudlet> {
        assert_eq!(
            self.mode,
            RetentionMode::Retained,
            "materialize needs RetentionMode::Retained"
        );
        let mut out: Vec<Cloudlet> = (0..self.registered as usize)
            .filter(|&i| {
                matches!(self.status[i], CloudletStatus::Success | CloudletStatus::Failed)
            })
            .map(|i| Cloudlet {
                id: self.ext_id[i] as usize,
                user_id: self.user[i] as usize,
                length_mi: self.length_mi[i],
                pes: self.pes[i] as usize,
                status: self.status[i],
                vm_id: match self.vm[i] {
                    NO_VM => None,
                    v => Some(v as usize),
                },
                submit_time: self.submit[i],
                start_time: self.start[i],
                finish_time: self.finish[i],
            })
            .collect();
        out.sort_by_key(|c| c.id);
        out
    }

    /// Per-tenant streaming reports, in tenant-id order.
    pub fn tenant_reports(&self) -> Vec<TenantReport> {
        self.tenants
            .iter()
            .map(|(&tenant, agg)| {
                let mut sum = 0.0;
                let mut count = 0u64;
                for (_, va) in self.vm_aggs.range((tenant, 0)..=(tenant, u32::MAX)) {
                    sum += va.sum_turnaround;
                    count += va.count;
                }
                debug_assert_eq!(count, agg.completed);
                TenantReport {
                    tenant,
                    registered: agg.registered,
                    completed: agg.completed,
                    failed: agg.failed,
                    rebound: agg.rebound,
                    retries_exhausted: agg.retries_exhausted,
                    sum_turnaround: sum,
                    mean_turnaround: if count > 0 { sum / count as f64 } else { 0.0 },
                    p50_turnaround: digest_quantile(&agg.buckets, agg.completed, 0.50),
                    p99_turnaround: digest_quantile(&agg.buckets, agg.completed, 0.99),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_cloudlet(id: usize, vm: Option<usize>, status: CloudletStatus) -> Cloudlet {
        let mut c = Cloudlet::new(id, id % 3, 1000 + id as u64, 1);
        c.vm_id = vm;
        c.status = status;
        c
    }

    #[test]
    fn retained_materialize_round_trips_exactly() {
        let mut s = CloudletStore::new(RetentionMode::Retained);
        let a = s.register(&sample_cloudlet(1, Some(7), CloudletStatus::Queued), 0);
        let b = s.register(&sample_cloudlet(0, None, CloudletStatus::Failed), 0);
        s.record_fail(b, 0, false);
        s.mark_dispatched(1);
        s.record_finish(a, 0, 7, 0.25, 0.25, 2.75);
        let out = s.materialize();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, 0, "sorted by display id");
        assert_eq!(out[0].status, CloudletStatus::Failed);
        assert_eq!(out[1].id, 1);
        assert_eq!(out[1].status, CloudletStatus::Success);
        assert_eq!(out[1].vm_id, Some(7));
        assert_eq!(out[1].submit_time.to_bits(), 0.25f64.to_bits());
        assert_eq!(out[1].finish_time.to_bits(), 2.75f64.to_bits());
        assert_eq!(out[1].length_mi, 1001);
    }

    #[test]
    fn non_terminal_cloudlets_stay_out_of_materialize() {
        let mut s = CloudletStore::new(RetentionMode::Retained);
        s.register(&sample_cloudlet(0, Some(1), CloudletStatus::Queued), 0);
        assert!(s.materialize().is_empty(), "in-flight at sim end is not a result");
    }

    #[test]
    fn streaming_matches_retained_aggregates_bit_for_bit() {
        let mut r = CloudletStore::new(RetentionMode::Retained);
        let mut s = CloudletStore::new(RetentionMode::Streaming);
        for store in [&mut r, &mut s] {
            for i in 0..100usize {
                let tenant = (i % 4) as u32;
                let c = sample_cloudlet(i, Some(i % 8), CloudletStatus::Queued);
                let id = store.register(&c, tenant);
                store.mark_dispatched(1);
                let submit = i as f64 * 0.125;
                let finish = submit + 1.5 + (i % 7) as f64 * 0.25;
                store.record_finish(id, tenant, (i % 8) as u32, submit, submit, finish);
            }
        }
        let (ra, sa) = (r.tenant_reports(), s.tenant_reports());
        assert_eq!(ra.len(), sa.len());
        for (x, y) in ra.iter().zip(&sa) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.sum_turnaround.to_bits(), y.sum_turnaround.to_bits());
            assert_eq!(x.mean_turnaround.to_bits(), y.mean_turnaround.to_bits());
            assert_eq!(x.p50_turnaround.to_bits(), y.p50_turnaround.to_bits());
            assert_eq!(x.p99_turnaround.to_bits(), y.p99_turnaround.to_bits());
        }
        assert_eq!(s.peak_active(), r.peak_active());
        assert!(
            s.peak_heap_bytes() < r.peak_heap_bytes(),
            "streaming drops the per-cloudlet rows"
        );
    }

    #[test]
    fn digest_quantiles_land_within_bucket_tolerance() {
        let mut s = CloudletStore::new(RetentionMode::Streaming);
        let mut exact: Vec<f64> = Vec::new();
        for i in 0..1000usize {
            let c = sample_cloudlet(i, Some(0), CloudletStatus::Queued);
            let id = s.register(&c, 0);
            s.mark_dispatched(1);
            let turnaround = 0.01 + (i as f64) * 0.01; // 0.01 .. 10.0
            exact.push(turnaround);
            s.record_finish(id, 0, 0, 0.0, 0.0, turnaround);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rep = &s.tenant_reports()[0];
        let tol = DIGEST_LOG10_SPAN / DIGEST_BUCKETS as f64; // one bucket in log10
        for (q, got) in [(0.50, rep.p50_turnaround), (0.99, rep.p99_turnaround)] {
            let want = exact[((q * 1000.0).ceil() as usize - 1).min(999)];
            let dl = (got.log10() - want.log10()).abs();
            assert!(dl <= tol + 1e-12, "q={q}: got {got}, want {want}, dlog {dl}");
        }
    }

    #[test]
    fn peak_active_tracks_high_water_mark() {
        let mut s = CloudletStore::new(RetentionMode::Streaming);
        let mut ids = Vec::new();
        for i in 0..10usize {
            ids.push(s.register(&sample_cloudlet(i, Some(0), CloudletStatus::Queued), 0));
        }
        s.mark_dispatched(10);
        for (i, id) in ids.iter().enumerate().take(6) {
            s.record_finish(*id, 0, 0, 0.0, 0.0, 1.0 + i as f64);
        }
        s.mark_dispatched(2);
        assert_eq!(s.active_now(), 6);
        assert_eq!(s.peak_active(), 10, "peak is the high-water mark, not current");
    }

    #[test]
    fn crash_interrupt_and_retry_accounting_conserves() {
        let mut s = CloudletStore::new(RetentionMode::Streaming);
        let mut ids = Vec::new();
        for i in 0..4usize {
            ids.push(s.register(&sample_cloudlet(i, Some(0), CloudletStatus::Queued), 1));
        }
        s.mark_dispatched(4);
        // the datacenter crashes with all four in flight
        s.record_crash_interrupt(4);
        assert_eq!(s.active_now(), 0, "crash drains the in-flight gauge");
        // broker re-binds three, drops one with its budget exhausted
        s.record_rebound(1, 3);
        s.mark_dispatched(3);
        s.record_retry_exhausted(1, 1);
        s.record_fail(ids[3], 1, false);
        for (i, id) in ids.iter().take(3).enumerate() {
            s.record_finish(*id, 1, 0, 0.0, 0.0, 1.0 + i as f64);
        }
        let rep = &s.tenant_reports()[0];
        assert_eq!(rep.registered, 4);
        assert_eq!(rep.completed, 3);
        assert_eq!(rep.failed, 1, "exhausted retries land in failed");
        assert_eq!(rep.rebound, 3);
        assert_eq!(rep.retries_exhausted, 1);
        assert_eq!(rep.completed + rep.failed, rep.registered, "nothing vanishes");
        assert_eq!(s.active_now(), 0);
    }

    #[test]
    fn bucket_edges_monotone_and_clamped() {
        assert!(bucket_edge(0) < bucket_edge(1));
        assert_eq!(bucket_of(0.0), 0, "zero turnaround clamps to the low edge");
        assert_eq!(bucket_of(1e12), DIGEST_BUCKETS - 1, "huge values clamp high");
        let b = bucket_of(1.0);
        assert!(bucket_edge(b) <= 1.0 && 1.0 < bucket_edge(b + 1));
    }
}
