//! Host: a physical machine inside a datacenter, aggregating PEs and RAM,
//! and hosting VMs (§2.1.1: "Multiple hosts are created inside data
//! centers").

use crate::sim::pe::{Pe, PeStatus};
use crate::sim::vm::Vm;

/// A physical host.
#[derive(Debug, Clone)]
pub struct Host {
    /// Id within its datacenter.
    pub id: usize,
    /// Processing elements (uniform MIPS per §2.1.1).
    pub pes: Vec<Pe>,
    /// Total RAM (MB).
    pub ram_mb: u64,
    /// RAM currently allocated to VMs.
    pub used_ram_mb: u64,
    /// VM ids placed here.
    pub vms: Vec<usize>,
}

impl Host {
    /// A host with `n_pes` PEs of `mips` each and `ram_mb` of memory.
    pub fn new(id: usize, n_pes: usize, mips: u64, ram_mb: u64) -> Self {
        Self {
            id,
            pes: (0..n_pes).map(|i| Pe::new(i, mips)).collect(),
            ram_mb,
            used_ram_mb: 0,
            vms: Vec::new(),
        }
    }

    /// Number of free PEs.
    pub fn free_pes(&self) -> usize {
        self.pes.iter().filter(|p| p.is_free()).count()
    }

    /// MIPS rating of this host's PEs.
    pub fn mips_per_pe(&self) -> u64 {
        self.pes.first().map(|p| p.mips).unwrap_or(0)
    }

    /// Whether the host can accept the VM (PEs, MIPS rating, RAM).
    pub fn is_suitable_for(&self, vm: &Vm) -> bool {
        self.free_pes() >= vm.pes
            && self.mips_per_pe() >= vm.mips
            && self.ram_mb - self.used_ram_mb >= vm.ram_mb
    }

    /// Allocate the VM; returns false when unsuitable.
    pub fn allocate(&mut self, vm: &Vm) -> bool {
        if !self.is_suitable_for(vm) {
            return false;
        }
        let mut need = vm.pes;
        for pe in &mut self.pes {
            if need == 0 {
                break;
            }
            if pe.is_free() {
                pe.status = PeStatus::Busy;
                need -= 1;
            }
        }
        self.used_ram_mb += vm.ram_mb;
        self.vms.push(vm.id);
        true
    }

    /// Release the VM's resources; returns false when the VM is not here.
    pub fn deallocate(&mut self, vm: &Vm) -> bool {
        let Some(pos) = self.vms.iter().position(|&v| v == vm.id) else {
            return false;
        };
        self.vms.remove(pos);
        self.used_ram_mb = self.used_ram_mb.saturating_sub(vm.ram_mb);
        let mut free = vm.pes;
        for pe in &mut self.pes {
            if free == 0 {
                break;
            }
            if pe.status == PeStatus::Busy {
                pe.status = PeStatus::Free;
                free -= 1;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_deallocate() {
        let mut h = Host::new(0, 8, 3400, 12_288);
        let vm = Vm::new(0, 0, 1000, 2, 1024, 1000);
        assert!(h.is_suitable_for(&vm));
        assert!(h.allocate(&vm));
        assert_eq!(h.free_pes(), 6);
        assert_eq!(h.used_ram_mb, 1024);
        assert!(h.deallocate(&vm));
        assert_eq!(h.free_pes(), 8);
        assert_eq!(h.used_ram_mb, 0);
        assert!(!h.deallocate(&vm), "double-free rejected");
    }

    #[test]
    fn rejects_oversized_vm() {
        let mut h = Host::new(0, 2, 1000, 2048);
        let too_many_pes = Vm::new(0, 0, 500, 4, 512, 1);
        assert!(!h.allocate(&too_many_pes));
        let too_fast = Vm::new(1, 0, 2000, 1, 512, 1);
        assert!(!h.allocate(&too_fast));
        let too_big = Vm::new(2, 0, 500, 1, 4096, 1);
        assert!(!h.allocate(&too_big));
    }

    #[test]
    fn fills_up() {
        let mut h = Host::new(0, 4, 1000, 4096);
        for i in 0..4 {
            assert!(h.allocate(&Vm::new(i, 0, 1000, 1, 1024, 1)));
        }
        assert!(!h.allocate(&Vm::new(9, 0, 1000, 1, 1, 1)), "no PEs left");
        assert_eq!(h.vms.len(), 4);
    }
}
