//! The CloudSim substrate: a from-scratch discrete-event cloud simulator
//! with the entity model of CloudSim 3.x (§2.1.1, Fig 2.1).
//!
//! * [`des`] — the discrete-event engine (run loop, clock, cancellation).
//! * [`queue`] — pluggable future event queues: the seed `BinaryHeap` and
//!   the indexed two-tier calendar queue, cross-checkable bit-for-bit.
//! * [`event`] — event tags and payloads (Fig 2.1 scheduling operations).
//! * [`pe`], [`host`], [`vm`], [`cloudlet`] — the entity model: processing
//!   elements with MIPS ratings, hosts aggregating PEs, VMs placed on
//!   hosts, cloudlets (applications) running on VMs.
//! * [`vm_allocation`] — `VmAllocationPolicySimple` (most free PEs first).
//! * [`cloudlet_scheduler`] — space-shared and time-shared cloudlet
//!   schedulers (id-based; per-cloudlet state lives in the store).
//! * [`cloudlet_store`] — the struct-of-arrays cloudlet arena: dense
//!   `CloudletId`s, retained-vs-streaming retention, per-tenant digests,
//!   pooled submit buffers. The memory backbone of megascale runs.
//! * [`datacenter`] — the IaaS resource provider entity.
//! * [`broker`] — `DatacenterBroker`: VM creation and round-robin
//!   application scheduling; tenant-aware, with optional streaming
//!   cloudlet sources; the extension point the paper's distributed
//!   brokers subclass.
//! * [`scenario`] — glue: build + run a whole scenario (single- or
//!   multi-tenant), producing the scheduling decisions and accounting
//!   data the distribution layer consumes.

pub mod broker;
pub mod cloudlet;
pub mod cloudlet_scheduler;
pub mod cloudlet_store;
pub mod datacenter;
pub mod des;
pub mod event;
pub mod host;
pub mod pe;
pub mod queue;
pub mod scenario;
pub mod vm;
pub mod vm_allocation;

pub use cloudlet::{Cloudlet, CloudletStatus};
pub use cloudlet_store::{CloudletId, CloudletStore, RetentionMode, SharedStore, TenantId, TenantReport};
pub use host::Host;
pub use pe::{Pe, PeStatus};
pub use scenario::{run_scenario, MultiTenantResult, ScenarioResult};
pub use vm::Vm;
