//! VM→Host allocation policies.
//!
//! `VmAllocationPolicySimple` is CloudSim's default: place each VM on the
//! suitable host with the most free PEs (load balancing by core count).

use crate::sim::host::Host;
use crate::sim::vm::Vm;

/// Strategy for placing VMs on hosts.
pub trait VmAllocationPolicy {
    /// Choose a host index for `vm`, or `None` when no host fits.
    fn select_host(&self, hosts: &[Host], vm: &Vm) -> Option<usize>;
}

/// CloudSim's `VmAllocationPolicySimple`: most free PEs first.
#[derive(Debug, Default, Clone)]
pub struct VmAllocationPolicySimple;

impl VmAllocationPolicy for VmAllocationPolicySimple {
    fn select_host(&self, hosts: &[Host], vm: &Vm) -> Option<usize> {
        hosts
            .iter()
            .enumerate()
            .filter(|(_, h)| h.is_suitable_for(vm))
            .max_by_key(|(i, h)| (h.free_pes(), usize::MAX - i)) // stable tie-break: lowest index
            .map(|(i, _)| i)
    }
}

/// First-fit policy (used by ablation benches: cheaper but less balanced).
#[derive(Debug, Default, Clone)]
pub struct VmAllocationFirstFit;

impl VmAllocationPolicy for VmAllocationFirstFit {
    fn select_host(&self, hosts: &[Host], vm: &Vm) -> Option<usize> {
        hosts.iter().position(|h| h.is_suitable_for(vm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hosts() -> Vec<Host> {
        vec![
            Host::new(0, 4, 1000, 4096),
            Host::new(1, 8, 1000, 4096),
            Host::new(2, 2, 1000, 4096),
        ]
    }

    #[test]
    fn simple_prefers_most_free_pes() {
        let hs = hosts();
        let vm = Vm::new(0, 0, 1000, 1, 512, 1);
        let p = VmAllocationPolicySimple;
        assert_eq!(p.select_host(&hs, &vm), Some(1));
    }

    #[test]
    fn simple_balances_over_time() {
        let mut hs = hosts();
        let p = VmAllocationPolicySimple;
        let mut placements = Vec::new();
        for i in 0..6 {
            let vm = Vm::new(i, 0, 1000, 2, 256, 1);
            let h = p.select_host(&hs, &vm).unwrap();
            assert!(hs[h].allocate(&vm));
            placements.push(h);
        }
        // 8-PE host absorbs more VMs but others get used as it drains
        assert!(placements.contains(&0));
        assert!(placements.contains(&1));
    }

    #[test]
    fn first_fit_takes_first_suitable() {
        let hs = hosts();
        let vm = Vm::new(0, 0, 1000, 1, 512, 1);
        assert_eq!(VmAllocationFirstFit.select_host(&hs, &vm), Some(0));
    }

    #[test]
    fn none_when_nothing_fits() {
        let hs = hosts();
        let vm = Vm::new(0, 0, 9999, 1, 512, 1);
        assert_eq!(VmAllocationPolicySimple.select_host(&hs, &vm), None);
        assert_eq!(VmAllocationFirstFit.select_host(&hs, &vm), None);
    }

    #[test]
    fn stable_tie_break() {
        let hs = vec![Host::new(0, 4, 1000, 4096), Host::new(1, 4, 1000, 4096)];
        let vm = Vm::new(0, 0, 1000, 1, 512, 1);
        assert_eq!(
            VmAllocationPolicySimple.select_host(&hs, &vm),
            Some(0),
            "equal free PEs → lowest index"
        );
    }
}
