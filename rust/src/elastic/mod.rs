//! The elastic middleware platform (§3.2, §4.3): health monitoring,
//! dynamic scaling (Algorithm 4), the AdaptiveScalerProbe (Algorithm 5)
//! and IntelligentAdaptiveScaler (Algorithm 6) over the grid's atomic
//! flags, IaaS provisioning, and multi-tenant coordination.
//!
//! "The developed middleware platform and elastic strategy is generic
//! enough such that it is not limited to CloudSim simulations" (§4.3) —
//! nothing here depends on `crate::sim` except the demo driver.

pub mod coordinator;
pub mod driver;
pub mod health;
pub mod ias;
pub mod probe;
pub mod provision;
pub mod scaler;

pub use coordinator::Coordinator;
pub use driver::{run_adaptive, ElasticReport, LoadRow, ScaleAction, ScaleEvent};
pub use health::{HealthMeasure, HealthMonitor, HealthSample};
pub use ias::{IasAction, IntelligentAdaptiveScaler};
pub use probe::{AdaptiveScalerProbe, SCALING_KEY, TERMINATE_ALL_FLAG};
pub use provision::{CloudProvisioner, LocalCluster, SimEc2};
pub use scaler::{DynamicScaler, ScaleDecision};
