//! `IntelligentAdaptiveScaler` — Algorithm 6 (§3.2.2, design #3).
//!
//! One IAS runs on every node of `cluster-sub` (the control plane). Nodes
//! *without* an Initiator in the main cluster watch the `toScaleOut` flag;
//! nodes *with* one watch `toScaleIn`. The shared atomic [`SCALING_KEY`]
//! makes the spawn/shutdown decision exclusive: the first CAS winner acts,
//! everyone else backs off — "This ensures 0 or 1 of Initiator instances
//! in each node, and avoids unnecessary hits to the Hazelcast distributed
//! objects".

use crate::elastic::probe::{flag_key, SCALING_KEY, TERMINATE_ALL_FLAG};
use crate::error::Result;
use crate::grid::cluster::{GridCluster, NodeId};

/// What an IAS probe iteration did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IasAction {
    /// This IAS spawned an Initiator into the main cluster.
    Spawned,
    /// This IAS shut its Initiator down.
    Shutdown,
    /// Terminate-all observed: the IAS stopped.
    Terminated,
    /// Nothing to do (flag unset, lost the race, or cooling down).
    Idle,
}

/// Per-sub-node IAS state.
#[derive(Debug)]
pub struct IntelligentAdaptiveScaler {
    /// This IAS's member in the sub-cluster.
    pub sub_node: NodeId,
    /// Tenant this IAS serves.
    pub tenant: String,
    /// The Initiator this node contributed to the main cluster, if any.
    pub initiator: Option<NodeId>,
    /// Virtual time before which no new decision is taken
    /// (`timeBetweenScalingDecisions`).
    cooldown_until: f64,
    /// Anti-cascade wait after acting.
    pub time_between_scaling_decisions: f64,
    terminated: bool,
}

impl IntelligentAdaptiveScaler {
    /// `procedure INITHEALTHMAP` — ensure flags exist (idempotent).
    pub fn init_health_map(sub: &mut GridCluster, me: NodeId, tenant: &str) -> Result<()> {
        for flag in ["toScaleOut", "toScaleIn"] {
            let key = flag_key(tenant, flag);
            let cur: Option<bool> = sub.map_get(me, "nodeHealth", key.clone())?;
            if cur.is_none() {
                sub.map_put(me, "nodeHealth", key, &false)?;
            }
        }
        Ok(())
    }

    /// New IAS on a sub-cluster node.
    pub fn new(sub_node: NodeId, tenant: &str, time_between_scaling_decisions: f64) -> Self {
        Self {
            sub_node,
            tenant: tenant.to_string(),
            initiator: None,
            cooldown_until: 0.0,
            time_between_scaling_decisions,
            terminated: false,
        }
    }

    /// True once terminate-all was observed.
    pub fn is_terminated(&self) -> bool {
        self.terminated
    }

    /// One `PROBE` iteration (Algorithm 6). `main` is the tenant's
    /// simulation cluster that Initiators join/leave.
    pub fn probe(&mut self, sub: &mut GridCluster, main: &mut GridCluster) -> Result<IasAction> {
        if self.terminated {
            return Ok(IasAction::Terminated);
        }
        // a fault-plan crash may have killed our Initiator out from under
        // us: forget it instead of later shutting down a ghost member
        if let Some(init) = self.initiator {
            if main.offset_of(init).is_err() {
                self.initiator = None;
            }
        }
        let me = self.sub_node;
        // terminate-all check (§4.3.2)
        if sub.atomic_get(me, SCALING_KEY) == TERMINATE_ALL_FLAG {
            self.terminated = true;
            if let Some(init) = self.initiator.take() {
                if main.size() > 1 {
                    main.leave(init)?;
                }
            }
            return Ok(IasAction::Terminated);
        }
        let now = sub.clock(me);
        if now < self.cooldown_until {
            return Ok(IasAction::Idle);
        }
        if self.initiator.is_none() {
            // monitoring for scale-out (instances.count() == 0 branch)
            let out_key = flag_key(&self.tenant, "toScaleOut");
            let flagged: Option<bool> = sub.map_get(me, "nodeHealth", out_key.clone())?;
            if flagged == Some(true) {
                // set to false before the atomic decision
                sub.map_put(me, "nodeHealth", out_key, &false)?;
                // Atomic{ currentValue ← key; key ← 1 }
                let current = sub.atomic_get_and_set(me, SCALING_KEY, 1);
                if current == 0 {
                    let id = main.join(); // spawnInstance()
                    self.initiator = Some(id);
                    self.cooldown_until =
                        sub.clock(me) + self.time_between_scaling_decisions;
                    sub.atomic_set(me, SCALING_KEY, 0);
                    return Ok(IasAction::Spawned);
                }
                // lost the race: restore the key only if it still holds our
                // marker — the winner resets it itself
            }
        } else {
            // monitoring for scale-in
            let in_key = flag_key(&self.tenant, "toScaleIn");
            let flagged: Option<bool> = sub.map_get(me, "nodeHealth", in_key.clone())?;
            if flagged == Some(true) {
                sub.map_put(me, "nodeHealth", in_key, &false)?;
                let current = sub.atomic_get_and_set(me, SCALING_KEY, -1);
                if current == 0 {
                    let init = self.initiator.take().expect("has initiator");
                    if main.size() > 1 {
                        main.leave(init)?; // shutdownInstance()
                    }
                    self.cooldown_until =
                        sub.clock(me) + self.time_between_scaling_decisions;
                    sub.atomic_set(me, SCALING_KEY, 0);
                    return Ok(IasAction::Shutdown);
                }
            }
        }
        Ok(IasAction::Idle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::probe::AdaptiveScalerProbe;
    use crate::grid::cluster::GridConfig;

    fn clusters(subs: usize) -> (GridCluster, GridCluster) {
        let sub = GridCluster::with_members(GridConfig::default(), subs);
        let main = GridCluster::with_members(
            GridConfig {
                backup_count: 1, // elastic runs need backups (§3.4.3)
                ..GridConfig::default()
            },
            1,
        );
        (sub, main)
    }

    #[test]
    fn exactly_one_ias_spawns() {
        let (mut sub, mut main) = clusters(4);
        let subs = sub.members();
        let mut probe = AdaptiveScalerProbe::new();
        probe.add_instance();
        probe.probe(&mut sub, subs[0], "t0").unwrap();
        let mut iases: Vec<IntelligentAdaptiveScaler> = subs
            .iter()
            .map(|&s| IntelligentAdaptiveScaler::new(s, "t0", 30.0))
            .collect();
        for ias in &mut iases {
            IntelligentAdaptiveScaler::init_health_map(&mut sub, ias.sub_node, "t0").unwrap();
        }
        let actions: Vec<IasAction> = iases
            .iter_mut()
            .map(|i| i.probe(&mut sub, &mut main).unwrap())
            .collect();
        let spawned = actions.iter().filter(|a| **a == IasAction::Spawned).count();
        assert_eq!(spawned, 1, "exactly one instance takes the action: {actions:?}");
        assert_eq!(main.size(), 2);
        // flag consumed: further probes do nothing
        for i in &mut iases {
            assert_ne!(i.probe(&mut sub, &mut main).unwrap(), IasAction::Spawned);
        }
        assert_eq!(main.size(), 2);
    }

    #[test]
    fn scale_in_by_owner_only() {
        let (mut sub, mut main) = clusters(2);
        let subs = sub.members();
        let mut a = IntelligentAdaptiveScaler::new(subs[0], "t0", 0.0);
        let mut b = IntelligentAdaptiveScaler::new(subs[1], "t0", 0.0);
        // a spawns
        let mut probe = AdaptiveScalerProbe::new();
        probe.add_instance();
        probe.probe(&mut sub, subs[0], "t0").unwrap();
        assert_eq!(a.probe(&mut sub, &mut main).unwrap(), IasAction::Spawned);
        // scale-in request: only a (who owns an Initiator) can act
        probe.remove_instance();
        probe.probe(&mut sub, subs[0], "t0").unwrap();
        assert_eq!(b.probe(&mut sub, &mut main).unwrap(), IasAction::Idle);
        assert_eq!(a.probe(&mut sub, &mut main).unwrap(), IasAction::Shutdown);
        assert_eq!(main.size(), 1);
    }

    #[test]
    fn terminate_all_stops_everyone() {
        let (mut sub, mut main) = clusters(3);
        let subs = sub.members();
        let mut iases: Vec<_> = subs
            .iter()
            .map(|&s| IntelligentAdaptiveScaler::new(s, "t0", 0.0))
            .collect();
        // spawn one initiator first
        let mut probe = AdaptiveScalerProbe::new();
        probe.add_instance();
        probe.probe(&mut sub, subs[0], "t0").unwrap();
        let _ = iases[0].probe(&mut sub, &mut main).unwrap();
        assert_eq!(main.size(), 2);
        probe.terminate_all(&mut sub, subs[0]);
        for ias in &mut iases {
            assert_eq!(ias.probe(&mut sub, &mut main).unwrap(), IasAction::Terminated);
            assert!(ias.is_terminated());
        }
        assert_eq!(main.size(), 1, "initiators left the main cluster");
    }

    #[test]
    fn crashed_initiator_is_forgotten() {
        let (mut sub, mut main) = clusters(1);
        let s0 = sub.members()[0];
        let mut ias = IntelligentAdaptiveScaler::new(s0, "t0", 0.0);
        IntelligentAdaptiveScaler::init_health_map(&mut sub, s0, "t0").unwrap();
        let mut probe = AdaptiveScalerProbe::new();
        probe.add_instance();
        probe.probe(&mut sub, s0, "t0").unwrap();
        assert_eq!(ias.probe(&mut sub, &mut main).unwrap(), IasAction::Spawned);
        let init = ias.initiator.expect("spawned an initiator");
        // the fault plan kills the Initiator behind the IAS's back
        main.leave(init).unwrap();
        // a scale-in request must not shut down the ghost member
        probe.remove_instance();
        probe.probe(&mut sub, s0, "t0").unwrap();
        assert_eq!(ias.probe(&mut sub, &mut main).unwrap(), IasAction::Idle);
        assert!(ias.initiator.is_none(), "ghost initiator forgotten");
    }

    #[test]
    fn cooldown_blocks_consecutive_actions() {
        let (mut sub, mut main) = clusters(1);
        let s0 = sub.members()[0];
        let mut ias = IntelligentAdaptiveScaler::new(s0, "t0", 1000.0);
        let mut probe = AdaptiveScalerProbe::new();
        probe.add_instance();
        probe.probe(&mut sub, s0, "t0").unwrap();
        assert_eq!(ias.probe(&mut sub, &mut main).unwrap(), IasAction::Spawned);
        // request scale-in immediately: cooldown holds
        probe.remove_instance();
        probe.probe(&mut sub, s0, "t0").unwrap();
        assert_eq!(ias.probe(&mut sub, &mut main).unwrap(), IasAction::Idle);
    }
}
