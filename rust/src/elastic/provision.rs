//! IaaS provisioning for auto scaling beyond the local cluster (§3.2.1,
//! Fig 3.5): "When there is only a limited availability of resources in
//! the local computer clusters ... Cloud²Sim can be run on an actual cloud
//! infrastructure" via the Hazelcast/AWS join mechanism.
//!
//! No AWS here, so [`SimEc2`] simulates the provider: instance spawn
//! latency ≫ local joins, plus per-instance-hour cost accounting — this is
//! also what turns the adaptive scaler into the "cloud middleware
//! Platform-as-a-Service" costing of §3.4.3.

use crate::grid::cluster::NodeId;

/// An elastic infrastructure provider.
pub trait CloudProvisioner {
    /// Request an instance at virtual time `now`; returns when it will be
    /// ready to join the cluster.
    fn provision(&mut self, now: f64) -> f64;
    /// Release an instance at `now` (stops its billing).
    fn release(&mut self, now: f64);
    /// Accumulated cost up to `now` (currency units).
    fn cost(&self, now: f64) -> f64;
    /// Provider name.
    fn name(&self) -> &'static str;
}

/// Instant, free provisioning: the research-lab cluster.
#[derive(Debug, Default)]
pub struct LocalCluster {
    active: usize,
}

impl CloudProvisioner for LocalCluster {
    fn provision(&mut self, now: f64) -> f64 {
        self.active += 1;
        now
    }
    fn release(&mut self, _now: f64) {
        self.active = self.active.saturating_sub(1);
    }
    fn cost(&self, _now: f64) -> f64 {
        0.0
    }
    fn name(&self) -> &'static str {
        "local-cluster"
    }
}

/// Simulated EC2: spawn latency + hourly billing (billed per started hour,
/// as 2014-era EC2 did).
#[derive(Debug)]
pub struct SimEc2 {
    /// Boot + Hazelcast-join latency (s).
    pub spawn_latency: f64,
    /// Hourly rate per instance.
    pub hourly_rate: f64,
    /// `(started_at, released_at)` per instance.
    sessions: Vec<(f64, Option<f64>)>,
}

impl SimEc2 {
    /// m3.large-era defaults: 90 s boot, $0.266/h.
    pub fn new() -> Self {
        Self {
            spawn_latency: 90.0,
            hourly_rate: 0.266,
            sessions: Vec::new(),
        }
    }

    /// Number of instances ever provisioned.
    pub fn total_provisioned(&self) -> usize {
        self.sessions.len()
    }
}

impl Default for SimEc2 {
    fn default() -> Self {
        Self::new()
    }
}

impl CloudProvisioner for SimEc2 {
    fn provision(&mut self, now: f64) -> f64 {
        self.sessions.push((now, None));
        now + self.spawn_latency
    }

    fn release(&mut self, now: f64) {
        if let Some(s) = self.sessions.iter_mut().rev().find(|s| s.1.is_none()) {
            s.1 = Some(now);
        }
    }

    fn cost(&self, now: f64) -> f64 {
        self.sessions
            .iter()
            .map(|(start, end)| {
                let until = end.unwrap_or(now).max(*start);
                let hours = ((until - start) / 3600.0).ceil().max(1.0);
                hours * self.hourly_rate
            })
            .sum()
    }

    fn name(&self) -> &'static str {
        "sim-ec2"
    }
}

/// Marker type pairing a provisioned node with its provider session
/// (used by elastic drivers that mix local + IaaS capacity).
#[derive(Debug, Clone, Copy)]
pub struct ProvisionedNode {
    /// The grid member.
    pub node: NodeId,
    /// When it became usable.
    pub ready_at: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_is_instant_and_free() {
        let mut p = LocalCluster::default();
        assert_eq!(p.provision(5.0), 5.0);
        assert_eq!(p.cost(100.0), 0.0);
    }

    #[test]
    fn ec2_latency_and_billing() {
        let mut p = SimEc2::new();
        let ready = p.provision(0.0);
        assert!((ready - 90.0).abs() < 1e-9);
        // 30 minutes of use bills one full hour
        p.release(1800.0);
        assert!((p.cost(1800.0) - 0.266).abs() < 1e-9);
        // a second instance running 90 minutes bills two hours
        p.provision(0.0);
        p.release(5400.0);
        assert!((p.cost(5400.0) - 0.266 * 3.0).abs() < 1e-9);
        assert_eq!(p.total_provisioned(), 2);
    }

    #[test]
    fn unreleased_instances_keep_billing() {
        let mut p = SimEc2::new();
        p.provision(0.0);
        let c1 = p.cost(3600.0);
        let c2 = p.cost(7200.0);
        assert!(c2 > c1);
    }
}
