//! The elastic simulation driver: the loaded CloudSim scenario running
//! under adaptive scaling (§3.2.2, evaluated in §5.1.1 / Table 5.2 /
//! Fig 5.2's adaptive overlay).
//!
//! Wiring (Fig 3.6): the master node runs the simulation in
//! `cluster-main`, plus the health monitor and the `AdaptiveScalerProbe`
//! attached to `cluster-sub`. Every spare node runs an
//! `IntelligentAdaptiveScaler` in `cluster-sub`, ready to contribute an
//! Initiator to `cluster-main` when the load demands it — the BOINC-like
//! cycle-sharing model on a trusted private network (§3.2.3).

use crate::config::SimConfig;
use crate::dist::cost::*;
use crate::dist::hz_cloudsim::grid_config;
use crate::elastic::health::{HealthMeasure, HealthMonitor};
use crate::elastic::ias::{IasAction, IntelligentAdaptiveScaler};
use crate::elastic::probe::AdaptiveScalerProbe;
use crate::elastic::scaler::{DynamicScaler, ScaleDecision};
use crate::error::Result;
use crate::faults::{FaultEvent, FaultKind};
use crate::grid::cluster::{GridCluster, GridConfig};
use crate::runtime::workload::WorkloadModel;
use crate::sim::broker::RoundRobinBinder;
use crate::sim::scenario::run_scenario_with_binder;

/// One Table 5.2-style log row.
#[derive(Debug, Clone)]
pub struct LoadRow {
    /// Virtual time of the row.
    pub at: f64,
    /// Instances in the main cluster.
    pub instances: usize,
    /// Load average per instance (join order).
    pub loads: Vec<f64>,
    /// What happened ("Spawning Instance", "Health Monitoring", ...).
    pub event: String,
}

/// Direction of one membership change taken by the closed loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// An Initiator joined the main cluster.
    Out,
    /// An Initiator left the main cluster.
    In,
    /// A member was killed by the fault plan (`memberCrashAt`), taking its
    /// in-flight round share with it.
    Crash,
    /// The crashed member restarted and rejoined (`memberRejoinAt`).
    Rejoin,
    /// A member exhausted the reliable-delivery retry budget on a
    /// heartbeat and was evicted through the churn path (link faults).
    Unreachable,
}

impl std::fmt::Display for ScaleAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScaleAction::Out => write!(f, "out"),
            ScaleAction::In => write!(f, "in"),
            ScaleAction::Crash => write!(f, "crash"),
            ScaleAction::Rejoin => write!(f, "rejoin"),
            ScaleAction::Unreachable => write!(f, "unreachable"),
        }
    }
}

/// One membership change, as the bench pipeline and the anti-jitter
/// integration tests consume it.
#[derive(Debug, Clone, Copy)]
pub struct ScaleEvent {
    /// Virtual time of the event, relative to the run start.
    pub at: f64,
    /// Out (spawn) or In (shutdown).
    pub action: ScaleAction,
    /// Main-cluster size right after the event.
    pub instances_after: usize,
}

/// Result of an adaptive run.
#[derive(Debug, Clone)]
pub struct ElasticReport {
    /// Virtual execution time.
    pub sim_time_s: f64,
    /// Main-cluster size at the end (before terminate-all).
    pub final_instances: usize,
    /// Peak size reached.
    pub peak_instances: usize,
    /// Scale-out events taken.
    pub scale_outs: usize,
    /// Scale-in events taken.
    pub scale_ins: usize,
    /// The load/event log (Table 5.2).
    pub rows: Vec<LoadRow>,
    /// Structured membership-change log: every scale-out/in with its
    /// virtual timestamp, in order. The anti-jitter contract (§4.3.1) is
    /// asserted over this log: consecutive events are at least
    /// `timeBetweenScaling` apart, and `instances_after` never drops
    /// below one.
    pub events: Vec<ScaleEvent>,
    /// Cloudlets completed.
    pub cloudlets_ok: usize,
    /// Max process CPU load observed (Fig 5.5).
    pub max_process_cpu_load: f64,
    /// Members killed by the fault plan (0 without one).
    pub crashes: usize,
    /// Crashed members that restarted and rejoined.
    pub rejoins: usize,
    /// Round tasks lost to a crash and re-queued onto the survivors.
    pub tasks_reexecuted: u64,
    /// Map entries dropped with leavers across the whole run
    /// (`map.entries_lost` — non-zero only without backups).
    pub entries_lost: u64,
    /// Map entries promoted from backups and re-homed by partition
    /// rebuilds across the whole run (`map.entries_migrated`).
    pub entries_migrated: u64,
    /// Structured fault log in the simulation-wide [`FaultEvent`] format —
    /// the same fingerprintable surface the datacenter-crash scenarios
    /// emit, so grid-member and datacenter faults compare uniformly.
    pub fault_events: Vec<crate::faults::FaultEvent>,
    /// Members evicted after a heartbeat exhausted the delivery retry
    /// budget (0 without link faults).
    pub unreachable_evictions: usize,
    /// Network messages sent on the main cluster over the whole run.
    pub net_messages: u64,
    /// Network payload bytes moved on the main cluster.
    pub net_bytes: u64,
    /// Reliable-delivery ack-timeout retries (0 without link faults).
    pub net_retries: u64,
    /// Delivery attempts lost to drops or the partition window.
    pub net_dropped: u64,
    /// Duplicated deliveries discarded by receiver-side dedup.
    pub net_deduplicated: u64,
}

/// Run the loaded round-robin scenario with adaptive scaling over at most
/// `available_nodes` spare nodes. `measure` picks the health signal
/// (the paper uses process CPU load and load average).
pub fn run_adaptive(
    cfg: &SimConfig,
    available_nodes: usize,
    measure: HealthMeasure,
    model: &mut dyn WorkloadModel,
) -> Result<ElasticReport> {
    // elastic runs mandate synchronous backups (§3.4.3)
    let mut main_cfg = grid_config(cfg);
    main_cfg.backup_count = main_cfg.backup_count.max(1);
    let mut main = GridCluster::with_members(main_cfg, 1);
    let master = main.master()?;

    // cluster-sub: one member for the probe (master node) + one per spare
    let mut sub = GridCluster::with_members(
        GridConfig {
            seed: cfg.seed ^ 0x5AB,
            ..GridConfig::default()
        },
        1 + available_nodes,
    );
    let sub_members = sub.members();
    let probe_node = sub_members[0];
    let tenant = "t0";
    let mut probe = AdaptiveScalerProbe::new();
    let mut iases: Vec<IntelligentAdaptiveScaler> = sub_members[1..]
        .iter()
        .map(|&s| IntelligentAdaptiveScaler::new(s, tenant, cfg.time_between_scaling))
        .collect();
    for ias in &iases {
        IntelligentAdaptiveScaler::init_health_map(&mut sub, ias.sub_node, tenant)?;
    }
    let mut monitor = HealthMonitor::new(cfg.pes_per_host);
    let mut scaler = DynamicScaler::new(
        cfg.max_threshold,
        cfg.min_threshold,
        cfg.max_instances_to_be_spawned.min(available_nodes),
        cfg.time_between_scaling,
        cfg.time_between_health_checks,
    );

    let scenario = run_scenario_with_binder(cfg, false, Box::<RoundRobinBinder>::default());
    let t_start = main.barrier();
    monitor.sample(&main); // baseline

    // master pays the core event loop up front
    main.advance_busy(
        master,
        des_core_cost(scenario.successes(), scenario.vms.len()),
    );

    let mut rows: Vec<LoadRow> = Vec::new();
    let mut events: Vec<ScaleEvent> = Vec::new();
    let mut scale_outs = 0;
    let mut scale_ins = 0;
    let mut peak = 1;

    // deterministic fault plan (§noop without the memberCrashAt knob):
    // the crash fires on the first round at or past `memberCrashAt` once a
    // second member exists; the victim's share of that round's batch is
    // re-queued onto the survivors
    let plan = cfg.fault_plan();
    let mut crash_pending = plan.member_crash_at;
    let mut rejoin_pending: Option<f64> = None;
    let mut crashes = 0usize;
    let mut rejoins = 0usize;
    let mut tasks_reexecuted: u64 = 0;
    let mut fault_events: Vec<FaultEvent> = Vec::new();
    let mut unreachable_evictions = 0usize;
    // transport faults: arm the seeded link-fault layer on the main
    // cluster. A scheduled partition window cuts the first Initiator slot
    // (offset 1) off from the master; the heartbeat loop below then evicts
    // it once the delivery budget runs out. Fault-free plans leave the net
    // model untouched so clean virtual times stay bit-identical.
    main.net.arm_link_faults(&plan, t_start, vec![1]);

    // workload: remaining cloudlet MI lengths, re-partitioned every round
    // over whatever members currently exist
    let mut remaining: Vec<u64> = scenario.cloudlets.iter().map(|c| c.length_mi).collect();
    if plan.member_crash_at.is_some() {
        // under a crash plan, keep the per-cloudlet state in a distributed
        // map (the paper holds job state in Hazelcast maps): the crash
        // then observably re-homes the victim's share through its backups,
        // and the churn referee asserts the lost/migrated split. Fault-free
        // runs skip this so their virtual times stay bit-identical to the
        // pre-fault-model driver.
        for (i, len) in remaining.iter().enumerate() {
            main.map_put(master, "cloudletState", format!("cl-{i}"), len)?;
        }
    }
    let ws = model.working_set_bytes();
    let mut round = 0usize;
    while !remaining.is_empty() {
        round += 1;
        let members = main.members();
        let n = members.len();
        // resident pressure: remaining state spread over current members
        let per_node_ws = (remaining.len() as u64 / n as u64 + 1) * ws;
        for m in &members {
            // best-effort reservation: pressure, not admission, here
            let _ = main.reserve_scratch(*m, per_node_ws);
        }
        let batch_total = (WORKLOAD_ROUND_BATCH * n).min(remaining.len());
        let batch: Vec<u64> = remaining.drain(..batch_total).collect();
        // run the round's task bodies through the two-phase parallel
        // engine: each member's share + GC factor comes from its own
        // NodeCtx shard (real threads when the grid config asks for them,
        // identical virtual time either way)
        let shares: Vec<f64> = (0..n)
            .map(|i| {
                batch
                    .iter()
                    .skip(i)
                    .step_by(n)
                    .map(|&mi| model.virtual_cost(mi))
                    .sum()
            })
            .collect();
        main.execute_gc_shares(master, &shares);
        for m in &members {
            main.release_scratch(*m, per_node_ws);
        }
        main.barrier();
        if n > 1 {
            // shared (n−1)² coordination model from dist::cost — the same
            // superlinear γ the static distributed runs pay, deliberately
            // replacing the old linear per-round charge so adaptive and
            // static deployments price cluster growth identically
            let gamma = round_coordination_cost(n);
            for m in &members {
                main.advance(*m, gamma);
            }
        }

        // --- reliable heartbeats (link faults only) ---
        // the master pings every peer through the ack/retry layer; a peer
        // that exhausts the delivery budget is unreachable and evicted
        // through the same churn path a crash takes. Fault-free runs skip
        // this entirely, keeping their virtual times bit-identical.
        let mut evicted_peers = 0usize;
        if main.net.has_faults() && main.size() > 1 {
            for peer in main.members().into_iter().skip(1) {
                if !main.probe_member(master, peer)? {
                    unreachable_evictions += 1;
                    evicted_peers += 1;
                    events.push(ScaleEvent {
                        at: main.clock(master) - t_start,
                        action: ScaleAction::Unreachable,
                        instances_after: main.size(),
                    });
                }
            }
        }

        let now = main.clock(master);
        let mut event = format!("Health Monitoring (round {round})");
        if evicted_peers > 0 {
            event = format!("Member Unreachable - {evicted_peers} evicted");
        }

        // --- fault injection: member crash / rejoin ---
        if let Some(crash_at) = crash_pending {
            if now - t_start >= crash_at && main.size() > 1 {
                // victim: the youngest member (highest offset, never the
                // master) — its strided share of this round's batch dies
                // with it and is re-queued for the survivors
                let victim = members[n - 1];
                main.leave(victim)?;
                let mut requeued: Vec<u64> =
                    batch.iter().skip(n - 1).step_by(n).copied().collect();
                tasks_reexecuted += requeued.len() as u64;
                requeued.extend(remaining.iter().copied());
                remaining = requeued;
                crashes += 1;
                crash_pending = None;
                rejoin_pending = plan.member_rejoin_at;
                event = format!("Member Crash - I{}", n - 1);
                events.push(ScaleEvent {
                    at: now - t_start,
                    action: ScaleAction::Crash,
                    instances_after: main.size(),
                });
                fault_events.push(FaultEvent {
                    at: now - t_start,
                    kind: FaultKind::Crash,
                    member: (n - 1) as u64,
                    detail: format!("re-queued {} tasks onto {} survivors", tasks_reexecuted, main.size()),
                });
            }
        }
        if let Some(rejoin_at) = rejoin_pending {
            if now - t_start >= rejoin_at {
                main.join();
                rejoins += 1;
                rejoin_pending = None;
                event = "Member Rejoin".to_string();
                events.push(ScaleEvent {
                    at: now - t_start,
                    action: ScaleAction::Rejoin,
                    instances_after: main.size(),
                });
                fault_events.push(FaultEvent {
                    at: now - t_start,
                    kind: FaultKind::Rejoin,
                    member: main.size() as u64,
                    detail: format!("cluster back to {} members", main.size()),
                });
            }
        }

        // --- health monitoring + Algorithm 4 ---
        let samples = monitor.sample(&main);
        let master_sample = samples
            .iter()
            .find(|(m, _)| *m == master)
            .map(|(_, s)| *s)
            .expect("master sampled");
        let load = monitor.measure(&master_sample, measure);
        // keep the control plane's clocks in step with the simulation
        let sub_now = sub.max_clock();
        if now > sub_now {
            for s in sub.members() {
                sub.advance(s, now - sub_now);
            }
        }
        let decision = scaler.decide(now, load, main.size());
        match decision {
            ScaleDecision::Out => {
                probe.add_instance();
                probe.probe(&mut sub, probe_node, tenant)?;
                for ias in iases.iter_mut() {
                    if ias.probe(&mut sub, &mut main)? == IasAction::Spawned {
                        scale_outs += 1;
                        event = format!("Spawning Instance - I{}", main.size() - 1);
                        events.push(ScaleEvent {
                            at: now - t_start,
                            action: ScaleAction::Out,
                            instances_after: main.size(),
                        });
                        break;
                    }
                }
            }
            ScaleDecision::In => {
                probe.remove_instance();
                probe.probe(&mut sub, probe_node, tenant)?;
                for ias in iases.iter_mut() {
                    if ias.probe(&mut sub, &mut main)? == IasAction::Shutdown {
                        scale_ins += 1;
                        event = "Scaling In".to_string();
                        events.push(ScaleEvent {
                            at: now - t_start,
                            action: ScaleAction::In,
                            instances_after: main.size(),
                        });
                        break;
                    }
                }
            }
            ScaleDecision::None => {}
        }
        peak = peak.max(main.size());
        let loads: Vec<f64> = samples.iter().map(|(_, s)| s.load_average).collect();
        rows.push(LoadRow {
            at: now - t_start,
            instances: main.size(),
            loads,
            event,
        });
    }

    let final_instances = main.size();
    // completion: terminate-all (§4.3.2)
    probe.terminate_all(&mut sub, probe_node);
    for ias in iases.iter_mut() {
        let _ = ias.probe(&mut sub, &mut main)?;
        debug_assert!(ias.is_terminated());
    }
    let t_end = main.barrier();
    // transport fault log appends after the driver's own churn events —
    // same ordering contract as the MapReduce engine
    fault_events.extend(main.net.drain_fault_log());

    Ok(ElasticReport {
        sim_time_s: t_end - t_start,
        final_instances,
        peak_instances: peak,
        scale_outs,
        scale_ins,
        rows,
        events,
        cloudlets_ok: scenario.successes(),
        max_process_cpu_load: monitor.max_process_cpu_load,
        crashes,
        rejoins,
        tasks_reexecuted,
        entries_lost: main.metrics.counter("map.entries_lost"),
        entries_migrated: main.metrics.counter("map.entries_migrated"),
        fault_events,
        unreachable_evictions,
        net_messages: main.net.messages,
        net_bytes: main.net.bytes,
        net_retries: main.net.retries,
        net_dropped: main.net.dropped,
        net_deduplicated: main.net.deduplicated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::workload::NativeBurnModel;

    fn loaded_cfg() -> SimConfig {
        SimConfig {
            backup_count: 1,
            max_threshold: 0.20, // paper: "a CPU utilization of 0.20"
            min_threshold: 0.01,
            time_between_scaling: 40.0,
            ..SimConfig::default_round_robin(200, 400, true)
        }
    }

    #[test]
    fn adaptive_scales_out_under_load() {
        let mut model = NativeBurnModel::default();
        let r = run_adaptive(
            &loaded_cfg(),
            5,
            HealthMeasure::LoadAverage,
            &mut model,
        )
        .unwrap();
        assert!(r.scale_outs >= 1, "heavy load must trigger scale-out");
        assert!(r.peak_instances >= 2);
        assert!(
            r.peak_instances <= 6,
            "cannot exceed available nodes + master"
        );
        assert_eq!(r.cloudlets_ok, 400);
        assert!(!r.rows.is_empty());
        assert!(r.rows.iter().any(|row| row.event.contains("Spawning")));
        assert_eq!(
            r.events
                .iter()
                .filter(|e| e.action == ScaleAction::Out)
                .count(),
            r.scale_outs,
            "structured log mirrors the counters"
        );
        assert!(r.events.iter().all(|e| e.instances_after >= 1));
    }

    #[test]
    fn adaptive_beats_single_static_node() {
        let mut model = NativeBurnModel::default();
        let cfg = loaded_cfg();
        let adaptive = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model)
            .unwrap()
            .sim_time_s;
        let static1 = crate::dist::run_distributed(&cfg, 1).unwrap().sim_time_s;
        assert!(
            adaptive < static1 * 0.6,
            "adaptive scaling must relieve the single node: {adaptive} vs {static1}"
        );
    }

    #[test]
    fn small_simulation_stays_single_instance() {
        // §5.1.1: "Adaptive scaling was not observed in the other cases" —
        // a light run never crosses the threshold
        let mut model = NativeBurnModel::default();
        let cfg = SimConfig {
            backup_count: 1,
            max_threshold: 0.9, // high bar
            min_threshold: 0.0001,
            ..SimConfig::default_round_robin(20, 40, false)
        };
        let r = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model).unwrap();
        assert_eq!(r.scale_outs, 0, "{r:?}");
        assert_eq!(r.final_instances, 1);
    }

    #[test]
    fn churn_crash_and_rejoin_redistribute_work() {
        let mut model = NativeBurnModel::default();
        let cfg = SimConfig {
            member_crash_at: Some(5.0),
            member_rejoin_at: Some(15.0),
            ..loaded_cfg()
        };
        let r = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model).unwrap();
        assert_eq!(r.crashes, 1, "{r:?}");
        assert_eq!(r.rejoins, 1);
        assert!(r.tasks_reexecuted > 0, "the victim's round share is re-queued");
        assert!(r.events.iter().any(|e| e.action == ScaleAction::Crash));
        assert!(r.events.iter().any(|e| e.action == ScaleAction::Rejoin));
        let crash_at = r
            .events
            .iter()
            .find(|e| e.action == ScaleAction::Crash)
            .unwrap()
            .at;
        let rejoin_at = r
            .events
            .iter()
            .find(|e| e.action == ScaleAction::Rejoin)
            .unwrap()
            .at;
        assert!(crash_at >= 5.0 && rejoin_at >= 15.0 && rejoin_at > crash_at);
        // elastic runs mandate synchronous backups (§3.4.3): churn must
        // migrate the victim's entries, never lose them
        assert_eq!(r.entries_lost, 0);
        assert!(r.entries_migrated > 0, "the victim's map share re-homes");
        // data parity with a fault-free run: every cloudlet still finishes
        let mut referee_model = NativeBurnModel::default();
        let referee =
            run_adaptive(&loaded_cfg(), 5, HealthMeasure::LoadAverage, &mut referee_model)
                .unwrap();
        assert_eq!(r.cloudlets_ok, referee.cloudlets_ok);
        assert_eq!(referee.crashes, 0);
        assert_eq!(referee.tasks_reexecuted, 0);
    }

    #[test]
    fn lossy_links_delay_but_never_lose_work() {
        let mut model = NativeBurnModel::default();
        let cfg = SimConfig {
            link_drop_prob: 0.4,
            link_dup_prob: 1.0,
            link_jitter: 0.001,
            delivery_retry_budget: 16,
            delivery_backoff_base: 0.01,
            ..loaded_cfg()
        };
        let r = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model).unwrap();
        assert!(r.net_retries > 0, "drops force ack-timeout retries: {r:?}");
        assert!(
            r.net_deduplicated > 0,
            "dup probability 1.0 makes every delivered heartbeat arrive twice"
        );
        assert_eq!(r.unreachable_evictions, 0, "budget 16 always suffices here");
        assert!(r.fault_events.iter().any(|e| e.kind == FaultKind::LinkDrop));
        // data parity with a fault-free run: lossy links move clocks only
        let mut clean_model = NativeBurnModel::default();
        let clean =
            run_adaptive(&loaded_cfg(), 5, HealthMeasure::LoadAverage, &mut clean_model)
                .unwrap();
        assert_eq!(r.cloudlets_ok, clean.cloudlets_ok);
        assert_eq!(clean.net_retries, 0);
        assert_eq!(clean.net_deduplicated, 0);
        assert_eq!(clean.unreachable_evictions, 0);
    }

    #[test]
    fn partitioned_peer_is_evicted_through_the_churn_path() {
        let mut model = NativeBurnModel::default();
        let cfg = SimConfig {
            link_partition_at: Some(0.0), // window opens at once, never heals
            delivery_retry_budget: 3,
            delivery_backoff_base: 0.01,
            ..loaded_cfg()
        };
        let r = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model).unwrap();
        assert!(r.unreachable_evictions >= 1, "{r:?}");
        assert!(r.events.iter().any(|e| e.action == ScaleAction::Unreachable));
        assert!(
            r.fault_events
                .iter()
                .any(|e| e.kind == FaultKind::MemberUnreachable),
            "evictions surface in the fingerprintable fault log"
        );
        assert!(r.rows.iter().any(|row| row.event.contains("Unreachable")));
        assert_eq!(r.cloudlets_ok, 400, "evictions delay work, never lose it");
    }

    #[test]
    fn load_rows_look_like_table_5_2() {
        let mut model = NativeBurnModel::default();
        let r = run_adaptive(&loaded_cfg(), 5, HealthMeasure::LoadAverage, &mut model).unwrap();
        // after a spawn, rows carry one more load column
        let spawn_row = r
            .rows
            .iter()
            .position(|row| row.event.contains("Spawning"))
            .expect("a spawn event");
        if spawn_row + 1 < r.rows.len() {
            assert!(r.rows[spawn_row + 1].loads.len() >= 2);
        }
        // load averages live in the paper's 0.0–1.0 band
        for row in &r.rows {
            for &l in &row.loads {
                assert!((0.0..=1.5).contains(&l), "load {l}");
            }
        }
    }
}
