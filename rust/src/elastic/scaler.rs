//! The dynamic scaling decision loop — Algorithm 4 (§3.2).
//!
//! ```text
//! while TRUE:
//!   getCurrentSystemHealthStatus()
//!   if load ≥ maxThreshold AND spawned < maxInstancesToBeSpawned:
//!     scaleOut(); wait(timeBetweenScaling)
//!   else if load ≤ minThreshold:
//!     scaleIn(); wait(timeBetweenScaling)
//!   else: wait(timeBetweenHealthChecks)
//! ```
//!
//! The long wait after a scaling action is the anti-jitter buffer: "This
//! longer wait between scaling decisions prevents cascaded scaling and
//! jitter" (§4.3.1); the wide threshold gap has the same purpose.

/// A scaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Add an instance.
    Out,
    /// Remove an instance.
    In,
    /// Do nothing this round.
    None,
}

/// Algorithm 4 state machine.
#[derive(Debug, Clone)]
pub struct DynamicScaler {
    /// `maxThreshold` on the monitored measure.
    pub max_threshold: f64,
    /// `minThreshold`.
    pub min_threshold: f64,
    /// `maxInstancesToBeSpawned`.
    pub max_instances: usize,
    /// Anti-jitter buffer after an action (virtual s).
    pub time_between_scaling: f64,
    /// Poll period (virtual s).
    pub time_between_health_checks: f64,
    /// Instances spawned so far by this scaler.
    pub spawned: usize,
    /// Next virtual time a decision may be taken.
    next_decision_at: f64,
}

impl DynamicScaler {
    /// Build from config-style parameters.
    pub fn new(
        max_threshold: f64,
        min_threshold: f64,
        max_instances: usize,
        time_between_scaling: f64,
        time_between_health_checks: f64,
    ) -> Self {
        assert!(
            max_threshold > min_threshold,
            "threshold gap must be positive (anti-jitter, §4.3.1)"
        );
        Self {
            max_threshold,
            min_threshold,
            max_instances,
            time_between_scaling,
            time_between_health_checks,
            spawned: 0,
            next_decision_at: 0.0,
        }
    }

    /// Evaluate one health observation at virtual time `now`; `instances`
    /// is the current main-cluster size.
    pub fn decide(&mut self, now: f64, load: f64, instances: usize) -> ScaleDecision {
        if now < self.next_decision_at {
            return ScaleDecision::None; // inside the anti-jitter buffer
        }
        if load >= self.max_threshold && self.spawned < self.max_instances {
            self.spawned += 1;
            self.next_decision_at = now + self.time_between_scaling;
            ScaleDecision::Out
        } else if load <= self.min_threshold && instances > 1 {
            self.spawned = self.spawned.saturating_sub(1);
            self.next_decision_at = now + self.time_between_scaling;
            ScaleDecision::In
        } else {
            self.next_decision_at = now + self.time_between_health_checks;
            ScaleDecision::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler() -> DynamicScaler {
        DynamicScaler::new(0.8, 0.1, 3, 30.0, 5.0)
    }

    #[test]
    fn scales_out_on_high_load() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 0.9, 1), ScaleDecision::Out);
        assert_eq!(s.spawned, 1);
    }

    #[test]
    fn anti_jitter_buffer_blocks_cascade() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 0.9, 1), ScaleDecision::Out);
        // still overloaded immediately after: no cascaded scale-out
        assert_eq!(s.decide(5.0, 0.95, 2), ScaleDecision::None);
        assert_eq!(s.decide(29.9, 0.95, 2), ScaleDecision::None);
        // after the buffer the next action is allowed
        assert_eq!(s.decide(30.0, 0.95, 2), ScaleDecision::Out);
    }

    #[test]
    fn respects_max_instances() {
        let mut s = scaler();
        let mut t = 0.0;
        for _ in 0..3 {
            assert_eq!(s.decide(t, 0.99, 1), ScaleDecision::Out);
            t += 31.0;
        }
        assert_eq!(s.decide(t, 0.99, 4), ScaleDecision::None, "cap reached");
    }

    #[test]
    fn scales_in_on_idle() {
        let mut s = scaler();
        s.decide(0.0, 0.9, 1); // out
        assert_eq!(s.decide(40.0, 0.05, 2), ScaleDecision::In);
    }

    #[test]
    fn never_scales_in_below_one_instance() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 0.0, 1), ScaleDecision::None);
    }

    #[test]
    fn mid_band_does_nothing() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 0.5, 2), ScaleDecision::None);
    }

    #[test]
    #[should_panic(expected = "threshold gap")]
    fn inverted_thresholds_rejected() {
        DynamicScaler::new(0.1, 0.8, 3, 30.0, 5.0);
    }
}
