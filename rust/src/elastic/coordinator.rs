//! Multi-tenant coordination (§3.1.2, Figs 3.4/3.7).
//!
//! A *tenant* is one experiment, mapped 1:1 to a cluster. The
//! `Coordinator` node holds instances in multiple clusters, sharing
//! information across tenants "through the local objects of the JVM", and
//! "prints the final output resulting from both experiments ... enabling a
//! combined view of multi-tenanted executions". Scaling state is keyed by
//! tenant id in the shared control cluster (§3.2.3).

use crate::config::SimConfig;
use crate::dist::hz_cloudsim::DistReport;
use crate::dist::{run_distributed, Strategy};
use crate::error::Result;
use crate::metrics::Table;

/// One tenant's declaration.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Tenant/experiment name (cluster group name).
    pub name: String,
    /// Its simulation configuration.
    pub config: SimConfig,
    /// Instances allocated to it.
    pub nodes: usize,
}

/// The coordinator: runs tenants as independent clusters and aggregates
/// their outputs.
pub struct Coordinator {
    tenants: Vec<Tenant>,
    /// Completed results per tenant.
    pub results: Vec<(String, DistReport)>,
}

impl Coordinator {
    /// New coordinator with no tenants.
    pub fn new() -> Self {
        Self {
            tenants: Vec::new(),
            results: Vec::new(),
        }
    }

    /// Register a tenant. Each gets an isolated cluster, so experiments
    /// are "independent and secured from the other parallel simulations"
    /// (§3.1.1); different seeds keep them decorrelated.
    pub fn add_tenant(&mut self, name: &str, mut config: SimConfig, nodes: usize) {
        config.seed ^= crate::util::rng::fnv1a64(name.as_bytes());
        self.tenants.push(Tenant {
            name: name.to_string(),
            config,
            nodes,
        });
    }

    /// Declared tenants.
    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Run every tenant (each in its own cluster; virtual times are
    /// per-tenant, i.e. tenants run in parallel as in Fig 3.4).
    pub fn run_all(&mut self) -> Result<()> {
        self.results.clear();
        for t in &self.tenants {
            let report = run_distributed(&t.config, t.nodes)?;
            self.results.push((t.name.clone(), report));
        }
        Ok(())
    }

    /// Wall-clock view of the whole deployment: tenants run in parallel,
    /// so the makespan is the slowest tenant.
    pub fn makespan(&self) -> f64 {
        self.results
            .iter()
            .map(|(_, r)| r.sim_time_s)
            .fold(0.0, f64::max)
    }

    /// The (Node × Experiment) deployment matrix of §3.1.2. `S` marks the
    /// tenant's master/supervisor, `I` Initiators, `C` the coordinator row.
    pub fn deployment_matrix(&self) -> String {
        let total_nodes: usize = self.tenants.iter().map(|t| t.nodes).max().unwrap_or(0);
        let mut headers: Vec<String> = vec!["node".into(), "cluster0".into()];
        headers.extend(self.tenants.iter().map(|t| t.name.clone()));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new("Deployment matrix (Node x Experiment)", &hdr_refs);
        for node in 0..total_nodes.max(1) {
            let mut row: Vec<String> = vec![format!("node{node}")];
            // the coordinator lives on node0 in cluster0
            row.push(if node == 0 { "C".into() } else { "-".into() });
            for t in &self.tenants {
                row.push(if node == 0 {
                    "S".into()
                } else if node < t.nodes {
                    "I".into()
                } else {
                    "-".into()
                });
            }
            table.row(&row);
        }
        table.render()
    }

    /// Combined final output across tenants (the coordinator's "combined
    /// view", §3.1.2).
    pub fn combined_report(&self) -> String {
        let mut t = Table::new(
            "Coordinator: combined multi-tenant results",
            &["tenant", "nodes", "time (s)", "cloudlets", "grid msgs"],
        );
        for (name, r) in &self.results {
            t.row(&[
                name.clone(),
                r.nodes.to_string(),
                format!("{:.3}", r.sim_time_s),
                r.cloudlets_ok.to_string(),
                r.grid_messages.to_string(),
            ]);
        }
        t.render()
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// Strategy note: multi-tenant deployments use [`Strategy::SimulatorInitiator`]
/// per tenant, coordinated externally (Fig 3.4).
pub const TENANT_STRATEGY: Strategy = Strategy::SimulatorInitiator;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenants_run_independently() {
        let mut c = Coordinator::new();
        c.add_tenant("exp1", SimConfig::default_round_robin(50, 100, false), 2);
        c.add_tenant("exp2", SimConfig::default_round_robin(30, 60, false), 3);
        c.run_all().unwrap();
        assert_eq!(c.results.len(), 2);
        assert!(c.results.iter().all(|(_, r)| r.cloudlets_ok > 0));
        assert!(c.makespan() > 0.0);
    }

    #[test]
    fn seeds_decorrelated_per_tenant() {
        let mut c = Coordinator::new();
        let base = SimConfig::default_round_robin(10, 20, false);
        c.add_tenant("a", base.clone(), 1);
        c.add_tenant("b", base, 1);
        assert_ne!(c.tenants()[0].config.seed, c.tenants()[1].config.seed);
    }

    #[test]
    fn matrix_renders_fig_3_4_shape() {
        let mut c = Coordinator::new();
        c.add_tenant("exp1", SimConfig::default_round_robin(10, 20, false), 2);
        c.add_tenant("exp2", SimConfig::default_round_robin(10, 20, false), 3);
        let m = c.deployment_matrix();
        assert!(m.contains("C"), "coordinator marked");
        assert!(m.contains("S"), "supervisors marked");
        assert!(m.contains("I"), "initiators marked");
        assert!(m.contains("node2"));
    }

    #[test]
    fn combined_report_lists_all() {
        let mut c = Coordinator::new();
        c.add_tenant("exp1", SimConfig::default_round_robin(10, 20, false), 1);
        c.run_all().unwrap();
        let rep = c.combined_report();
        assert!(rep.contains("exp1"));
    }
}
