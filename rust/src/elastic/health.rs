//! Health monitoring (§4.3.1).
//!
//! The paper's health monitor wraps `com.sun.management.OperatingSystemMXBean`
//! and samples process CPU load, system CPU load and load average. Here the
//! same signals are derived from the grid's virtual clocks: process CPU
//! load between two samples is Δbusy/Δclock of a node; the load average is
//! an exponentially-weighted average of it (per-core normalized), which is
//! what Table 5.2 logs during scaling events.

use crate::grid::cluster::{GridCluster, NodeId};
use crate::util::stats::Ewma;
use std::collections::BTreeMap;

/// Which signal drives scaling decisions (configurable, §4.3.1: "This can
/// also be done using the other system characteristics monitored").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthMeasure {
    /// Busy fraction of the monitored process between samples.
    ProcessCpuLoad,
    /// EWMA of the busy fraction, normalized per core (UNIX load-average
    /// analog).
    LoadAverage,
    /// Heap occupancy fraction.
    HeapPct,
}

/// One sample of one node.
#[derive(Debug, Clone, Copy)]
pub struct HealthSample {
    /// Virtual time of the sample.
    pub at: f64,
    /// Process CPU load in `[0,1]`.
    pub process_cpu_load: f64,
    /// Load average (EWMA, per-core).
    pub load_average: f64,
    /// Heap occupancy in `[0,1]`.
    pub heap_pct: f64,
}

#[derive(Debug, Clone)]
struct NodeTrack {
    last_clock: f64,
    last_busy: f64,
    load_avg: Ewma,
}

/// The monitor: tracks per-node deltas between samples.
#[derive(Debug)]
pub struct HealthMonitor {
    cores: usize,
    tracks: BTreeMap<NodeId, NodeTrack>,
    /// Full sample history `(node, sample)` for reporting (Table 5.2).
    pub history: Vec<(NodeId, HealthSample)>,
    /// Max process CPU load ever observed (Fig 5.5).
    pub max_process_cpu_load: f64,
}

impl HealthMonitor {
    /// `cores` normalizes the load average (the paper's testbed: 8-thread
    /// i7-2600K nodes).
    pub fn new(cores: usize) -> Self {
        Self {
            cores: cores.max(1),
            tracks: BTreeMap::new(),
            history: Vec::new(),
            max_process_cpu_load: 0.0,
        }
    }

    /// Sample every member; returns the fresh samples in member order.
    pub fn sample(&mut self, cluster: &GridCluster) -> Vec<(NodeId, HealthSample)> {
        let mut out = Vec::new();
        for m in cluster.members() {
            let clock = cluster.clock(m);
            let busy = cluster.busy(m);
            let track = self.tracks.entry(m).or_insert_with(|| NodeTrack {
                last_clock: clock,
                last_busy: busy,
                load_avg: Ewma::new(0.4),
            });
            let d_clock = (clock - track.last_clock).max(1e-9);
            let d_busy = (busy - track.last_busy).clamp(0.0, d_clock);
            let p = d_busy / d_clock;
            let la = track.load_avg.update(p / self.cores as f64 * 2.0);
            track.last_clock = clock;
            track.last_busy = busy;
            let heap = cluster.heap_used(m) as f64 / cluster.cfg.node_heap_bytes as f64;
            let s = HealthSample {
                at: clock,
                process_cpu_load: p,
                load_average: la,
                heap_pct: heap,
            };
            self.max_process_cpu_load = self.max_process_cpu_load.max(p);
            self.history.push((m, s));
            out.push((m, s));
        }
        out
    }

    /// Extract the configured measure from a sample.
    pub fn measure(&self, s: &HealthSample, which: HealthMeasure) -> f64 {
        match which {
            HealthMeasure::ProcessCpuLoad => s.process_cpu_load,
            HealthMeasure::LoadAverage => s.load_average,
            HealthMeasure::HeapPct => s.heap_pct,
        }
    }

    /// Forget a departed node's track.
    pub fn forget(&mut self, node: NodeId) {
        self.tracks.remove(&node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::cluster::GridConfig;

    #[test]
    fn busy_node_reads_high_load() {
        let mut c = GridCluster::with_members(GridConfig::default(), 2);
        let ms = c.members();
        let mut mon = HealthMonitor::new(8);
        mon.sample(&c); // baseline
        c.advance_busy(ms[0], 10.0); // fully busy
        c.advance(ms[1], 10.0); // idle
        let samples = mon.sample(&c);
        assert!(samples[0].1.process_cpu_load > 0.95);
        assert!(samples[1].1.process_cpu_load < 0.05);
        assert!(mon.max_process_cpu_load > 0.95);
    }

    #[test]
    fn load_average_smooths() {
        let mut c = GridCluster::with_members(GridConfig::default(), 1);
        let m = c.members()[0];
        let mut mon = HealthMonitor::new(8);
        mon.sample(&c);
        // one busy burst then idle: load average decays, not jumps
        c.advance_busy(m, 10.0);
        let s1 = mon.sample(&c)[0].1;
        c.advance(m, 10.0);
        let s2 = mon.sample(&c)[0].1;
        assert!(s2.process_cpu_load < 0.05);
        assert!(s2.load_average > 0.0 && s2.load_average < s1.load_average + 1e-12);
    }

    #[test]
    fn heap_pct_tracked() {
        let cfg = GridConfig {
            node_heap_bytes: 1000,
            ..GridConfig::default()
        };
        let mut c = GridCluster::with_members(cfg, 1);
        let m = c.members()[0];
        c.reserve_scratch(m, 500).unwrap();
        let mut mon = HealthMonitor::new(8);
        let s = mon.sample(&c)[0].1;
        assert!((s.heap_pct - 0.5).abs() < 0.1);
        assert_eq!(mon.measure(&s, HealthMeasure::HeapPct), s.heap_pct);
    }
}
