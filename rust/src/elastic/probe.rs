//! `AdaptiveScalerProbe` — Algorithm 5 (§3.2.2).
//!
//! Runs in the master node's JVM alongside the health monitor, but is
//! attached to the *sub-cluster* (`cluster-sub`). The health monitor flips
//! local atomic booleans (`addInstance`/`removeInstance`); `probe()`
//! publishes them into the sub-cluster's distributed `nodeHealth` map,
//! where the IntelligentAdaptiveScaler instances of the other nodes see
//! them. On completion the probe broadcasts `TERMINATE_ALL_FLAG` so every
//! main-cluster instance shuts down (§4.3.2).

use crate::error::Result;
use crate::grid::cluster::{GridCluster, NodeId};

/// The distributed flag value ordering all instances to shut down.
pub const TERMINATE_ALL_FLAG: i64 = -999;

/// Name of the shared atomic used for scaling decisions (§4.3.2: a
/// Hazelcast `IAtomicLong`).
pub const SCALING_KEY: &str = "key";

/// The probe thread's state.
#[derive(Debug, Default)]
pub struct AdaptiveScalerProbe {
    to_scale_out: bool,
    to_scale_in: bool,
}

impl AdaptiveScalerProbe {
    /// New idle probe.
    pub fn new() -> Self {
        Self::default()
    }

    /// `procedure ADDINSTANCE`: the health monitor requests a scale-out.
    pub fn add_instance(&mut self) {
        self.to_scale_out = true;
    }

    /// `procedure REMOVEINSTANCE`.
    pub fn remove_instance(&mut self) {
        self.to_scale_in = true;
    }

    /// One `PROBE` loop iteration: publish pending local flags into the
    /// sub-cluster's `nodeHealth` map (mutually exclusive, as in the
    /// pseudocode). `me` is the probe's sub-cluster member.
    pub fn probe(&mut self, sub: &mut GridCluster, me: NodeId, tenant: &str) -> Result<()> {
        if self.to_scale_out {
            self.to_scale_out = false;
            sub.map_put(me, "nodeHealth", flag_key(tenant, "toScaleOut"), &true)?;
            sub.map_put(me, "nodeHealth", flag_key(tenant, "toScaleIn"), &false)?;
        } else if self.to_scale_in {
            self.to_scale_in = false;
            sub.map_put(me, "nodeHealth", flag_key(tenant, "toScaleIn"), &true)?;
            sub.map_put(me, "nodeHealth", flag_key(tenant, "toScaleOut"), &false)?;
        }
        Ok(())
    }

    /// Completion: notify every instance to terminate (§4.3.2).
    pub fn terminate_all(&self, sub: &mut GridCluster, me: NodeId) {
        sub.atomic_set(me, SCALING_KEY, TERMINATE_ALL_FLAG);
    }
}

/// Per-tenant flag keys: the multi-tenant coordinator maps scaling flags
/// against the cluster/tenant id (§3.2.3: "distributed hash maps ...
/// mapping the scaling decisions and health information against the
/// cluster or tenant ID").
pub fn flag_key(tenant: &str, flag: &str) -> String {
    format!("{flag}@{tenant}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::cluster::GridConfig;

    #[test]
    fn probe_publishes_flags() {
        let mut sub = GridCluster::with_members(GridConfig::default(), 2);
        let me = sub.members()[0];
        let mut p = AdaptiveScalerProbe::new();
        p.add_instance();
        p.probe(&mut sub, me, "t0").unwrap();
        let out: Option<bool> = sub.map_get(me, "nodeHealth", flag_key("t0", "toScaleOut")).unwrap();
        assert_eq!(out, Some(true));
        let inn: Option<bool> = sub.map_get(me, "nodeHealth", flag_key("t0", "toScaleIn")).unwrap();
        assert_eq!(inn, Some(false));
        // flag consumed locally
        p.probe(&mut sub, me, "t0").unwrap();
        let out: Option<bool> = sub.map_get(me, "nodeHealth", flag_key("t0", "toScaleOut")).unwrap();
        assert_eq!(out, Some(true), "probe without new request leaves map untouched");
    }

    #[test]
    fn scale_in_overrides_out_flag() {
        let mut sub = GridCluster::with_members(GridConfig::default(), 1);
        let me = sub.members()[0];
        let mut p = AdaptiveScalerProbe::new();
        p.add_instance();
        p.probe(&mut sub, me, "t0").unwrap();
        p.remove_instance();
        p.probe(&mut sub, me, "t0").unwrap();
        let out: Option<bool> = sub.map_get(me, "nodeHealth", flag_key("t0", "toScaleOut")).unwrap();
        let inn: Option<bool> = sub.map_get(me, "nodeHealth", flag_key("t0", "toScaleIn")).unwrap();
        assert_eq!(out, Some(false));
        assert_eq!(inn, Some(true));
    }

    #[test]
    fn terminate_broadcasts() {
        let mut sub = GridCluster::with_members(GridConfig::default(), 2);
        let me = sub.members()[0];
        let p = AdaptiveScalerProbe::new();
        p.terminate_all(&mut sub, me);
        let other = sub.members()[1];
        assert_eq!(sub.atomic_get(other, SCALING_KEY), TERMINATE_ALL_FLAG);
    }

    #[test]
    fn tenant_flags_isolated() {
        assert_ne!(flag_key("t0", "toScaleOut"), flag_key("t1", "toScaleOut"));
    }
}
