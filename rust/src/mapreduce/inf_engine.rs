//! Infinispan-profile MapReduce simulator (`InfMapReduceSimulator`, §4.2.1).
//!
//! "Infinisim in the compatibility layer configures the
//! DefaultCacheManager ... A transactional cache is created from the cache
//! manager. An instance of cache in Infinispan is similar to an instance
//! in Hazelcast" — same engine, Infinispan cost/semantic profile
//! (JGroups-clustered, mature MR, efficient local mode).

use crate::error::Result;
use crate::faults::FaultPlan;
use crate::grid::backend::BackendProfile;
use crate::grid::cluster::{GridCluster, GridConfig};
use crate::grid::serialize::InMemoryFormat;
use crate::mapreduce::corpus::Corpus;
use crate::mapreduce::engine::MapReduceEngine;
use crate::mapreduce::job::{JobConfig, JobResult};
use crate::mapreduce::wordcount::{WordCountMapper, WordCountReducer};

/// Grid configuration for Infinispan-profile MR. `workers` stays at the
/// sequential default; the `run_inf_wordcount*` entry points choose the
/// executor worker count.
pub fn inf_mr_grid_config(node_heap_bytes: u64, seed: u64) -> GridConfig {
    GridConfig {
        backend: BackendProfile::infinispan_like(),
        in_memory_format: InMemoryFormat::Object,
        node_heap_bytes,
        seed,
        ..GridConfig::default()
    }
}

/// Run the default word-count job on an Infinispan-profile cluster,
/// using every available core for the map phase.
pub fn run_inf_wordcount(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
) -> Result<JobResult> {
    let workers = crate::mapreduce::default_workers();
    run_inf_wordcount_with_workers(corpus, job, instances, node_heap_bytes, workers)
}

/// [`run_inf_wordcount`] with an explicit executor worker count
/// (`workers = 1` forces the sequential engine; virtual-time results are
/// identical either way — used by the seq-vs-threaded wall-clock benches).
pub fn run_inf_wordcount_with_workers(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
    workers: usize,
) -> Result<JobResult> {
    run_inf_wordcount_faulted(
        corpus,
        job,
        instances,
        node_heap_bytes,
        workers,
        FaultPlan::default(),
    )
}

/// [`run_inf_wordcount_with_workers`] under a deterministic fault plan.
/// A no-op plan takes the exact fault-free code path, so the fault
/// scenarios can use the same entry point for headline and referee runs.
pub fn run_inf_wordcount_faulted(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
    workers: usize,
    plan: FaultPlan,
) -> Result<JobResult> {
    let mapper = WordCountMapper;
    let reducer = WordCountReducer;
    let engine = MapReduceEngine::new(corpus, job, &mapper, &reducer).with_fault_plan(plan);
    let mut cluster = GridCluster::with_members(
        GridConfig {
            workers: workers.max(1),
            ..inf_mr_grid_config(node_heap_bytes, 0x1F5 ^ instances as u64)
        },
        instances,
    );
    engine.run(&mut cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::corpus::CorpusConfig;

    #[test]
    fn inf_wordcount_runs_fast_locally() {
        let corpus = Corpus::new(CorpusConfig {
            lines_per_file: 300,
            ..CorpusConfig::default()
        });
        let r = run_inf_wordcount(corpus, JobConfig::default(), 1, 64 * 1024 * 1024).unwrap();
        assert!(r.is_conserved());
        // mature local mode: the whole small job takes well under a minute
        assert!(r.sim_time_s < 60.0, "t={}", r.sim_time_s);
    }

    #[test]
    fn hz_and_inf_agree_on_results() {
        // identical design/tasks ⇒ identical outputs (§4: "the same
        // simulation code will run in both implementations")
        let mk = || {
            Corpus::new(CorpusConfig {
                lines_per_file: 250,
                ..CorpusConfig::default()
            })
        };
        let a = run_inf_wordcount(mk(), JobConfig::default(), 3, 64 * 1024 * 1024).unwrap();
        let b = crate::mapreduce::hz_engine::run_hz_wordcount(
            mk(),
            JobConfig::default(),
            3,
            64 * 1024 * 1024,
        )
        .unwrap();
        assert_eq!(a.reduce_invocations, b.reduce_invocations);
        assert_eq!(a.top_words, b.top_words);
        assert_eq!(a.total_count, b.total_count);
    }
}
