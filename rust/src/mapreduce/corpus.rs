//! Synthetic USENET-like corpus generator.
//!
//! The paper's word-count benchmarks read "huge text files such as the
//! files collected from USENET Corpus" — 6–8 MB each, ≥125 000 lines
//! (§4.2.2, §5.2). That corpus is not available here, so this module
//! generates a deterministic equivalent: Zipf-distributed words over a
//! large vocabulary, so distinct-word counts (= `reduce()` invocations)
//! grow sublinearly with lines read, exactly the axis the paper sweeps.
//!
//! Generation is lazy — `line(file, line)` materializes one line at a time
//! — so "9.4 GB" sweeps never hold a corpus in (real) memory. Duplicated
//! file contents (`file % distinct_files`) reproduce the paper's trick of
//! increasing `map()` invocations while keeping `reduce()` constant
//! (§4.2.3: "By using duplicate files, invocations of map() are
//! increased, keeping the reduce() invocations constant").

use crate::util::rng::Pcg32;

/// Corpus shape parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    /// Total files presented to the job (`map()` invocations).
    pub files: usize,
    /// Distinct file contents; `files > distinct_files` duplicates.
    pub distinct_files: usize,
    /// Lines read per file (the paper's "MapReduce size").
    pub lines_per_file: usize,
    /// Words per line.
    pub words_per_line: usize,
    /// Vocabulary size (distinct possible words).
    pub vocab: usize,
    /// Zipf exponent for word frequencies.
    pub zipf_s: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            files: 3,
            distinct_files: 3,
            lines_per_file: 10_000,
            words_per_line: 12,
            vocab: 1_200_000,
            zipf_s: 0.9,
            seed: 0xC0DE_C0DE,
        }
    }
}

/// The generator.
#[derive(Debug, Clone)]
pub struct Corpus {
    /// Shape parameters.
    pub cfg: CorpusConfig,
}

impl Corpus {
    /// New corpus from config.
    pub fn new(cfg: CorpusConfig) -> Self {
        assert!(cfg.distinct_files >= 1);
        assert!(cfg.vocab >= 2);
        Self { cfg }
    }

    /// Word ids of one line. Deterministic in `(file % distinct_files,
    /// line)`.
    pub fn line_words(&self, file: usize, line: usize) -> Vec<u32> {
        let content_id = (file % self.cfg.distinct_files) as u64;
        let mut rng = Pcg32::new(
            self.cfg.seed ^ content_id.wrapping_mul(0x9E3779B97F4A7C15),
            line as u64,
        );
        (0..self.cfg.words_per_line)
            .map(|_| self.zipf_word(&mut rng))
            .collect()
    }

    fn zipf_word(&self, rng: &mut Pcg32) -> u32 {
        // inverse-CDF continuous approximation (see util::rng::gen_zipf)
        let n = self.cfg.vocab as f64;
        let s = self.cfg.zipf_s;
        let u = rng.next_f64().max(1e-12);
        let e = 1.0 - s;
        let x = if (s - 1.0).abs() < 1e-9 {
            (u * n.ln()).exp_m1()
        } else {
            let h = (n.powf(e) - 1.0) / e;
            (u * h * e + 1.0).powf(1.0 / e) - 1.0
        };
        (x.min(n - 1.0).max(0.0)) as u32
    }

    /// Render a line as text (the word-count mapper tokenizes this).
    pub fn line_text(&self, file: usize, line: usize) -> String {
        let mut s = String::new();
        self.line_text_into(file, line, &mut s);
        s
    }

    /// Allocation-light variant: render into a reusable buffer (the MR
    /// engine's map loop reuses one buffer per member — perf pass §L3).
    pub fn line_text_into(&self, file: usize, line: usize, out: &mut String) {
        out.clear();
        out.reserve(self.cfg.words_per_line * 9);
        let content_id = (file % self.cfg.distinct_files) as u64;
        let mut rng = Pcg32::new(
            self.cfg.seed ^ content_id.wrapping_mul(0x9E3779B97F4A7C15),
            line as u64,
        );
        let mut digits = [0u8; 10];
        for i in 0..self.cfg.words_per_line {
            if i > 0 {
                out.push(' ');
            }
            out.push('w');
            // manual integer formatting: no per-word String allocation
            let mut w = self.zipf_word(&mut rng);
            let mut n = 0;
            loop {
                digits[n] = b'0' + (w % 10) as u8;
                w /= 10;
                n += 1;
                if w == 0 {
                    break;
                }
            }
            for d in (0..n).rev() {
                out.push(digits[d] as char);
            }
        }
    }

    /// Approximate bytes of one file at the configured size — matches the
    /// paper's 6–8 MB per 125k-line file.
    pub fn file_bytes(&self) -> u64 {
        (self.cfg.lines_per_file * self.cfg.words_per_line * 7) as u64
    }

    /// Total corpus bytes across all files.
    pub fn total_bytes(&self) -> u64 {
        self.file_bytes() * self.cfg.files as u64
    }

    /// Total token count across all files.
    pub fn total_tokens(&self) -> u64 {
        (self.cfg.files * self.cfg.lines_per_file * self.cfg.words_per_line) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn deterministic_lines() {
        let c = Corpus::new(CorpusConfig::default());
        assert_eq!(c.line_words(0, 42), c.line_words(0, 42));
        assert_ne!(c.line_words(0, 42), c.line_words(0, 43));
    }

    #[test]
    fn duplicate_files_share_content() {
        let c = Corpus::new(CorpusConfig {
            files: 6,
            distinct_files: 3,
            ..CorpusConfig::default()
        });
        assert_eq!(c.line_words(0, 7), c.line_words(3, 7), "file 3 duplicates file 0");
        assert_ne!(c.line_words(0, 7), c.line_words(1, 7));
    }

    #[test]
    fn distinct_words_grow_sublinearly() {
        let c = Corpus::new(CorpusConfig::default());
        let distinct_at = |lines: usize| {
            let mut seen = HashSet::new();
            for l in 0..lines {
                for w in c.line_words(0, l) {
                    seen.insert(w);
                }
            }
            seen.len()
        };
        let d1 = distinct_at(500);
        let d4 = distinct_at(2000);
        assert!(d4 > d1, "more lines, more distinct words");
        assert!(
            (d4 as f64) < (d1 as f64) * 4.0,
            "sublinear: {d1} -> {d4} (zipf reuse)"
        );
        // reduce() invocations must be a large fraction of tokens at small
        // sizes (paper: 68k reduces from 360k tokens at size 10k)
        let tokens = 500 * 12;
        assert!(d1 * 3 > tokens / 4, "d1={d1} tokens={tokens}");
    }

    #[test]
    fn file_size_matches_paper_scale() {
        let c = Corpus::new(CorpusConfig {
            lines_per_file: 125_000,
            ..CorpusConfig::default()
        });
        let mb = c.file_bytes() as f64 / (1024.0 * 1024.0);
        assert!(mb > 5.0 && mb < 12.0, "paper: 6-8MB files, got {mb:.1}MB");
    }

    #[test]
    fn line_text_tokenizable() {
        let c = Corpus::new(CorpusConfig::default());
        let t = c.line_text(0, 0);
        assert_eq!(t.split_whitespace().count(), 12);
        assert!(t.split_whitespace().all(|w| w.starts_with('w')));
    }
}
