//! The MapReduce supervisor/engine shared by both backend profiles
//! (§3.4.2, Fig 3.11/3.12: same design, two implementations).
//!
//! Phases, all priced on the grid's virtual clocks:
//!
//! 1. **Input assignment** — files round-robin over members; each member
//!    reserves heap for its input buffers (the Fig 5.10 OOM mechanism).
//! 2. **Map** — members tokenize their files chunk-by-chunk through the
//!    user `Mapper`, paying the backend's per-chunk supervision overhead
//!    and retaining emitted-pair heap per the backend profile (the
//!    Fig 5.11 OOM mechanism — Hazelcast buffers unaggregated pairs).
//!    Word counting is *really performed* on the synthetic corpus.
//! 3. **Shuffle** — distinct keys move to their partition owners; the
//!    young-Hazelcast profile pays a per-key supervisor round-trip here
//!    (Table 5.3's 1→2-instance collapse).
//! 4. **Reduce** — owners fold their keys through the user `Reducer`.
//! 5. **Collect** — the supervisor (master) gathers the result;
//!    `reduce()` invocations = distinct keys, `map()` invocations = files.
//!
//! Phases 3–5 run through one of two pipelines selected by
//! [`JobConfig::pipeline`] (`mrPipeline`): the seed **sequential** tail, or
//! the owner-partitioned **parallel** tail where each owner's grouping and
//! fold run on real OS threads via the two-phase shard machinery and
//! collect k-way-merges the per-owner sorted results. Both tails execute
//! the same f64 operations in the same order per member, so every virtual
//! quantity (clocks, heap, invocation counts, top words) is bitwise
//! identical — `tests/props_mr.rs` fuzzes the contract and the
//! `megascale_wordcount` scenario referees it in-run at 2M+ distinct keys.
//! Mappers emit into partition-pre-hashed buckets: the partition id is
//! computed once per distinct key at emit time and cached, so neither
//! pipeline ever re-hashes a key during shuffle (see ARCHITECTURE.md §4).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::error::{C2SError, Result};
use crate::faults::{FaultEvent, FaultKind, FaultPlan};
use crate::grid::backend::BackendProfile;
use crate::grid::cluster::{GridCluster, NodeId};
use crate::grid::parallel::NodeCtx;
use crate::grid::partition::partition_of;
use crate::mapreduce::corpus::Corpus;
use crate::mapreduce::job::{
    merge_sorted_counts, top_n, top_n_pairs, JobConfig, JobResult, Mapper, MrPipeline, Reducer,
};

/// CPU cost of mapping one token (tokenize + emit) on the JVM (s).
const TOKEN_CPU_COST: f64 = 0.8e-6;
/// CPU cost of folding one value in a reducer (s).
const REDUCE_VALUE_CPU_COST: f64 = 0.1e-6;
/// Serialized bytes per shuffled key entry.
const SHUFFLE_ENTRY_BYTES: u64 = 24;

/// One mapper's combined output for one partition owner: `(key, count)`
/// pairs destined for that owner, in arbitrary (hash) order.
type OwnerBucket = Vec<(String, i64)>;
/// One mapper's full output: one [`OwnerBucket`] per member, plus the
/// member's distinct-key count (the shuffle wire-cost driver), retained
/// pair-heap bytes, emitted-pair count, and the total virtual cost the
/// member charged for its chunks (the straggler/speculation driver).
type MapOutput = (Vec<OwnerBucket>, u64, u64, u64, f64);
/// What either pipeline tail hands back to the shared collect/teardown
/// code: `reduce()` invocations, the total count, and the top words.
type TailOutput = (u64, i64, Vec<(String, i64)>);

/// The engine: corpus + job config + user code.
pub struct MapReduceEngine<'a> {
    /// Input corpus.
    pub corpus: Corpus,
    /// Job parameters.
    pub job: JobConfig,
    mapper: &'a dyn Mapper,
    reducer: &'a dyn Reducer,
    faults: Option<FaultPlan>,
}

impl<'a> MapReduceEngine<'a> {
    /// Build an engine.
    pub fn new(
        corpus: Corpus,
        job: JobConfig,
        mapper: &'a dyn Mapper,
        reducer: &'a dyn Reducer,
    ) -> Self {
        Self {
            corpus,
            job,
            mapper,
            reducer,
            faults: None,
        }
    }

    /// Inject a seeded fault schedule into the job (crash/re-execution,
    /// straggler skew, speculative backups). Faults change *timing* only:
    /// every data result stays bit-identical to the no-fault run — the
    /// referee contract `tests/props_faults.rs` fuzzes.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Run the job on the cluster. The master is the supervisor ("the
    /// master node hosts the supervisor of the MapReduce job", §3.4.2).
    pub fn run(&self, cluster: &mut GridCluster) -> Result<JobResult> {
        let members = cluster.members();
        let n = members.len();
        if n == 0 {
            return Err(C2SError::MapReduce("cluster has no members".into()));
        }
        let master = cluster.master()?;
        let t_start = cluster.barrier();
        let backend = cluster.cfg.backend.clone();
        // Infinispan "operates better as a local cache" (§5.2): local-mode
        // compute discount on a single instance.
        let local_factor = if n == 1 { backend.local_mode_factor } else { 1.0 };

        // ---- Transport faults: arm the lossy/partitioned-link layer ----
        // The minority side of a scheduled partition is the youngest
        // ⌈n/8⌉ members (the scenario's 2|14 split on 16 nodes); it elects
        // its own master at cut time and merges back on heal. Everything
        // below rides the reliable-delivery layer, so a clean plan leaves
        // every send bit-for-bit a plain transfer.
        let plan = self.faults.clone().unwrap_or_default();
        let crash_off = plan.crash_offset(n);
        let mut fault_events: Vec<FaultEvent> = Vec::new();
        let mut minority_offsets: Vec<usize> = Vec::new();
        if plan.has_link_faults() && n > 1 {
            if plan.link_partition_at.is_some() {
                minority_offsets = (n - (n / 8).max(1)..n).collect();
            }
            let minority: Vec<u64> = minority_offsets.iter().map(|&o| o as u64).collect();
            cluster.net.arm_link_faults(&plan, t_start, minority);
            if let Some(p_rel) = plan.link_partition_at {
                fault_events.push(FaultEvent {
                    at: p_rel,
                    kind: FaultKind::LinkPartition,
                    member: minority_offsets[0] as u64,
                    detail: format!(
                        "{}|{} member split",
                        minority_offsets.len(),
                        n - minority_offsets.len()
                    ),
                });
                let sub = cluster
                    .sub_master(&minority_offsets)
                    .expect("minority side is non-empty");
                fault_events.push(FaultEvent {
                    at: p_rel,
                    kind: FaultKind::SplitBrain,
                    member: minority_offsets[0] as u64,
                    detail: format!("minority elects {sub} as master"),
                });
            }
        }

        // ---- Phase 1: input assignment + admission ----
        // Work is split at *chunk* granularity (file, line-range) — like
        // the real grids' partition-based splits — so parallelism is not
        // capped by the file count. Each member buffers its chunk share.
        let files = self.corpus.cfg.files;
        let lines = self.corpus.cfg.lines_per_file;
        let chunk = self.job.chunk_lines.max(1);
        let mut chunks: Vec<(usize, usize, usize)> = Vec::new();
        for f in 0..files {
            let mut l = 0;
            while l < lines {
                chunks.push((f, l, (l + chunk).min(lines)));
                l += chunk;
            }
        }
        let file_bytes = self.corpus.file_bytes();
        let total_input = file_bytes * files as u64;
        let mut reserved: Vec<u64> = vec![0; n];
        for (i, m) in members.iter().enumerate() {
            let share = chunks.iter().skip(i).step_by(n).count() as u64;
            let input = total_input * share / chunks.len().max(1) as u64;
            cluster
                .reserve_scratch(*m, input)
                .map_err(|e| self.release_on_err(cluster, &members, &reserved, e))?;
            reserved[i] = input;
        }

        // ---- Phase 2: map (+ combine) ----
        // Member tasks run through the two-phase parallel engine: each body
        // owns its NodeCtx shard, so with `workers > 1` the real
        // tokenization work spreads over OS threads while virtual time
        // stays bitwise-identical to sequential execution.
        //
        // The combine map caches each distinct key's partition id at first
        // emit (one hash per distinct key, on the worker thread) and the
        // body splits its output into per-owner buckets before returning —
        // shuffle becomes a hand-off and never re-hashes a key.
        //
        // The fault plan fixes its crash victim before the phase starts:
        // the victim's body does no work (its map output would die with it
        // anyway — map output lives on the worker, Dean & Ghemawat §3.3)
        // and its chunks are re-executed on survivors below.
        let chunks_ref = &chunks;
        let map_backend = &backend;
        let partition_count = cluster.cfg.partition_count;
        let map_out = cluster.try_execute_on_all(master, |ctx| {
            if Some(ctx.offset()) == crash_off {
                let mut buckets: Vec<OwnerBucket> = Vec::new();
                buckets.resize_with(n, Vec::new);
                return Ok((buckets, 0, 0, 0, 0.0));
            }
            let mine = chunks_ref.iter().skip(ctx.offset()).step_by(n).copied();
            self.map_chunk_set(ctx, mine, n, partition_count, local_factor, map_backend)
        });
        let map_out: Vec<(NodeId, MapOutput)> = match map_out {
            Ok(r) => r,
            Err(e) => return Err(self.release_on_err(cluster, &members, &reserved, e)),
        };
        let mut bucketed: Vec<Vec<OwnerBucket>> = Vec::with_capacity(n);
        let mut distincts: Vec<u64> = Vec::with_capacity(n);
        let mut cost_sums: Vec<f64> = Vec::with_capacity(n);
        let mut emitted_total: u64 = 0;
        for (i, (_member, (buckets, distinct, retained, emitted, cost))) in
            map_out.into_iter().enumerate()
        {
            bucketed.push(buckets);
            distincts.push(distinct);
            cost_sums.push(cost);
            reserved[i] += retained;
            emitted_total += emitted;
        }

        // ---- Fault recovery + straggler injection (timing only) ----
        // Every chunk is still mapped exactly once and i64 folds commute,
        // so the data results below stay bit-identical to a no-fault run;
        // only clocks, heap peaks and sim_time_s may move.
        let mut tasks_reexecuted: u64 = 0;
        let mut speculative_wins: u64 = 0;
        if let Some(co) = crash_off {
            let crash_at = plan.member_crash_at.unwrap_or(0.0);
            let lost: Vec<(usize, usize, usize)> =
                chunks.iter().skip(co).step_by(n).copied().collect();
            fault_events.push(FaultEvent {
                at: crash_at,
                kind: FaultKind::Crash,
                member: co as u64,
                detail: format!("lost {} map chunks", lost.len()),
            });
            if !lost.is_empty() {
                let lost_ref = &lost;
                let reexec = cluster.try_execute_on_all(master, |ctx| {
                    let off = ctx.offset();
                    if off == co {
                        // the victim is still down while its work re-runs
                        let mut buckets: Vec<OwnerBucket> = Vec::new();
                        buckets.resize_with(n, Vec::new);
                        return Ok((buckets, 0, 0, 0, 0.0));
                    }
                    // survivors split the lost chunks round-robin by
                    // survivor rank, priced exactly like the primary pass
                    let rank = if off > co { off - 1 } else { off };
                    let mine = lost_ref.iter().skip(rank).step_by(n - 1).copied();
                    self.map_chunk_set(ctx, mine, n, partition_count, local_factor, map_backend)
                });
                let reexec = match reexec {
                    Ok(r) => r,
                    Err(e) => return Err(self.release_on_err(cluster, &members, &reserved, e)),
                };
                for (i, (_member, (buckets, distinct, retained, emitted, cost))) in
                    reexec.into_iter().enumerate()
                {
                    for (owner, bucket) in buckets.into_iter().enumerate() {
                        bucketed[i][owner].extend(bucket);
                    }
                    distincts[i] += distinct;
                    cost_sums[i] += cost;
                    reserved[i] += retained;
                    emitted_total += emitted;
                }
                tasks_reexecuted = lost.len() as u64;
                fault_events.push(FaultEvent {
                    at: crash_at,
                    kind: FaultKind::Reexecution,
                    member: co as u64,
                    detail: format!("{} chunks re-executed on {} survivors", lost.len(), n - 1),
                });
            }
            // the victim restarts (fail-fast when no memberRejoinAt is
            // scheduled) and pays the backend's instance-init cost before
            // it can make the phase barrier
            let rejoin_at = plan.member_rejoin_at.unwrap_or(crash_at);
            let victim = members[co];
            let restart = (t_start + rejoin_at).max(cluster.clock(victim)) + backend.init_cost;
            let dt = restart - cluster.clock(victim);
            cluster.advance(victim, dt);
            fault_events.push(FaultEvent {
                at: rejoin_at,
                kind: FaultKind::Rejoin,
                member: co as u64,
                detail: format!("restarted, init cost {}s", backend.init_cost),
            });
        }
        if let Some(s) = plan.straggler_offset(n) {
            // skew the straggler's accumulated map advances — multiplying
            // the total is identical to multiplying every per-chunk
            // advance, so the skew is exactly the two-phase executor's
            // virtual-time stretch without re-running the bodies
            if Some(s) != crash_off && cost_sums[s] > 0.0 {
                let skew = plan.slow_member_skew;
                let straggler = members[s];
                let extra = cost_sums[s] * (skew - 1.0);
                let clock_s = cluster.clock(straggler);
                fault_events.push(FaultEvent {
                    at: clock_s - t_start,
                    kind: FaultKind::Straggler,
                    member: s as u64,
                    detail: format!("skew {skew}x over map work"),
                });
                // backup candidates: everyone but the straggler and the
                // (dead or restarting) crash victim; least-loaded wins,
                // ties by offset — fully deterministic
                let backup = if plan.speculative.is_on() {
                    (0..n).filter(|&i| i != s && Some(i) != crash_off).min_by(
                        |&a, &b| {
                            cluster
                                .clock(members[a])
                                .partial_cmp(&cluster.clock(members[b]))
                                .expect("virtual clocks are finite")
                                .then(a.cmp(&b))
                        },
                    )
                } else {
                    None
                };
                match backup {
                    Some(b) => {
                        let clock_b = cluster.clock(members[b]);
                        let backup_finish = clock_b + cost_sums[s];
                        let straggler_finish = clock_s + extra;
                        if backup_finish < straggler_finish {
                            // first-result-wins: the backup copy finishes
                            // first and the straggler's attempt is killed
                            // there; the shared deterministic output makes
                            // the winner's identity timing-only
                            cluster.advance_busy(members[b], cost_sums[s]);
                            cluster.advance_busy(straggler, (backup_finish - clock_s).max(0.0));
                            let mut won = chunks.iter().skip(s).step_by(n).count() as u64;
                            if let Some(co) = crash_off {
                                let lost = chunks.iter().skip(co).step_by(n).count();
                                let rank = if s > co { s - 1 } else { s };
                                won += (0..lost).skip(rank).step_by(n - 1).count() as u64;
                            }
                            speculative_wins = won;
                            fault_events.push(FaultEvent {
                                at: backup_finish - t_start,
                                kind: FaultKind::SpeculativeWin,
                                member: s as u64,
                                detail: format!("backup member-{b} finished first"),
                            });
                        } else {
                            // the primary wins; the backup is killed when
                            // the primary's result lands
                            cluster.advance_busy(straggler, extra);
                            cluster.advance_busy(
                                members[b],
                                cost_sums[s].min(straggler_finish - clock_b).max(0.0),
                            );
                            fault_events.push(FaultEvent {
                                at: straggler_finish - t_start,
                                kind: FaultKind::SpeculativeLoss,
                                member: s as u64,
                                detail: format!("primary beat backup member-{b}"),
                            });
                        }
                    }
                    None => cluster.advance_busy(straggler, extra),
                }
            }
        }
        cluster.barrier();

        // ---- Phases 3–5: shuffle → reduce → collect ----
        // Two pipelines, one virtual-time contract: the parallel tail runs
        // the same f64 operations in the same order per member as the
        // sequential tail, so `mrPipeline` changes wall clock only.
        let (reduce_invocations, total_count, top_words) = match self.job.pipeline {
            MrPipeline::Sequential => {
                self.tail_sequential(cluster, &members, bucketed, &distincts, local_factor)
            }
            MrPipeline::Parallel => {
                self.tail_parallel(cluster, &members, bucketed, &distincts, local_factor)
            }
        };

        // ---- Split-brain heal: the minority merges back on link heal ----
        // Hazelcast-style: re-pay init, reconcile map entries, re-form the
        // partition table through the normal rebuild path. Runs before
        // collect so the final gather crosses a whole cluster again.
        let mut transport_split_brains = 0u32;
        if !minority_offsets.is_empty() {
            if let Some(h_abs) = cluster.net.faults.as_ref().and_then(|f| f.heal_at()) {
                let h_rel = h_abs - t_start;
                let reconciled = cluster
                    .split_brain_heal(&minority_offsets, h_abs)
                    .map_err(|e| self.release_on_err(cluster, &members, &reserved, e))?;
                transport_split_brains = 1;
                fault_events.push(FaultEvent {
                    at: h_rel,
                    kind: FaultKind::LinkHeal,
                    member: minority_offsets[0] as u64,
                    detail: "partition healed".into(),
                });
                fault_events.push(FaultEvent {
                    at: h_rel,
                    kind: FaultKind::SplitBrainMerge,
                    member: minority_offsets[0] as u64,
                    detail: format!(
                        "{} members re-merged, {reconciled} entries reconciled",
                        minority_offsets.len()
                    ),
                });
                cluster.barrier();
            }
        }

        // ---- Phase 5 (shared): collect at the supervisor ----
        let result_bytes = reduce_invocations * SHUFFLE_ENTRY_BYTES;
        if n > 1 {
            let d = cluster
                .reliable_send(n - 1, 0, result_bytes)
                .map_err(|e| self.release_on_err(cluster, &members, &reserved, e))?;
            cluster.advance_busy(master, d.cost);
        }
        let peak_heap = members.iter().map(|&m| cluster.heap_used(m)).max().unwrap_or(0);

        // Split-brain under long heavy distributed jobs (§4.3.3,
        // hazelcast#2359): sub-clusters form and later re-merge; each
        // incident costs a recovery/re-merge pause. Synchronous backups
        // keep the data safe, but wall time suffers — which is what
        // limited Hazelcast MR to shorter jobs in the paper.
        let provisional = cluster.max_clock() - t_start;
        let mut split_brain_events = 0u32;
        if n > 1 && backend.split_brain_under_load && provisional > 600.0 {
            split_brain_events = (provisional / 600.0) as u32;
            let penalty = split_brain_events as f64 * 15.0;
            for m in &members {
                cluster.advance(*m, penalty);
            }
            cluster.metrics.add("cluster.split_brain", split_brain_events as u64);
        }
        split_brain_events += transport_split_brains;

        // teardown
        for (i, m) in members.iter().enumerate() {
            cluster.release_scratch(*m, reserved[i]);
        }
        let t_end = cluster.barrier();

        // Transport drops/dups were logged in send order (all sends issue
        // from sequential supervisor code, so the order is worker-count
        // independent); they append after the engine-level events.
        fault_events.extend(cluster.net.drain_fault_log());

        Ok(JobResult {
            map_invocations: files as u64,
            reduce_invocations,
            sim_time_s: t_end - t_start,
            emitted_pairs: emitted_total,
            top_words,
            total_count,
            nodes: n,
            peak_heap,
            split_brain_events,
            tasks_reexecuted,
            speculative_wins,
            fault_events,
            net_messages: cluster.net.messages,
            net_bytes: cluster.net.bytes,
            net_retries: cluster.net.retries,
            net_dropped: cluster.net.dropped,
            net_deduplicated: cluster.net.deduplicated,
        })
    }

    /// Map one chunk set on one member shard — the body shared by the
    /// primary map pass and the crash-recovery re-execution pass, so both
    /// price, reserve and combine chunks identically.
    fn map_chunk_set(
        &self,
        ctx: &mut NodeCtx,
        chunks: impl Iterator<Item = (usize, usize, usize)>,
        n: usize,
        partition_count: u32,
        local_factor: f64,
        backend: &BackendProfile,
    ) -> Result<MapOutput> {
        let mut partial: HashMap<String, (u32, i64)> = HashMap::new();
        let mut retained: u64 = 0;
        let mut emitted: u64 = 0;
        let mut cost_sum: f64 = 0.0;
        let mut text = String::new(); // reused line buffer (perf pass §L3)
        for (f, l0, l1) in chunks {
            let gc = ctx.gc_factor();
            let mut tokens_in_chunk: u64 = 0;
            for line in l0..l1 {
                self.corpus.line_text_into(f, line, &mut text);
                self.mapper.map(f, line, &text, &mut |k, v| {
                    use std::collections::hash_map::Entry;
                    match partial.entry(k) {
                        Entry::Occupied(mut e) => e.get_mut().1 += v,
                        Entry::Vacant(e) => {
                            let pid = partition_of(e.key().as_bytes(), partition_count);
                            e.insert((pid, v));
                        }
                    }
                    tokens_in_chunk += 1;
                });
            }
            emitted += tokens_in_chunk;
            // pair-retention heap (the Hazelcast OOM mechanism)
            let pair_bytes = tokens_in_chunk * backend.mr_pair_retained_bytes;
            ctx.reserve_scratch(pair_bytes)?;
            retained += pair_bytes;
            let mut cost =
                backend.mr_chunk_overhead + tokens_in_chunk as f64 * TOKEN_CPU_COST * local_factor;
            if self.job.verbose {
                // verbose mode logs per-chunk progress (§5.2:
                // "executions were slower in verbose mode")
                cost += backend.mr_chunk_overhead * 0.5;
            }
            let charged = cost * gc;
            ctx.advance_busy(charged);
            cost_sum += charged;
        }
        // split into per-owner buckets on the worker thread, consuming
        // the cached partition ids
        let distinct = partial.len() as u64;
        let mut buckets: Vec<OwnerBucket> = Vec::new();
        buckets.resize_with(n, Vec::new);
        for (k, (pid, v)) in partial {
            let owner = pid as usize % n;
            // the satellite micro-assert: the owner derived from the
            // emit-time partition id must agree with a shuffle-time
            // re-hash (debug builds only — release never re-hashes)
            debug_assert_eq!(
                owner,
                partition_of(k.as_bytes(), partition_count) as usize % n,
                "emit-time and shuffle-time owners disagree for {k:?}"
            );
            buckets[owner].push((k, v));
        }
        Ok((buckets, distinct, retained, emitted, cost_sum))
    }

    /// Phase-3 wire costs: one reliable send per member to the supervisor
    /// (member order, offset 0 the destination). Both pipeline tails call
    /// this exact sequence from supervisor code, so the transport's
    /// sequence numbers, counters and fault draws advance identically —
    /// the tails stay bit-exact under link faults too. Clean plans make
    /// every send a plain [`crate::grid::net::NetModel::transfer`].
    fn shuffle_wires(cluster: &mut GridCluster, distincts: &[u64]) -> Vec<f64> {
        let n = distincts.len();
        if n <= 1 {
            return vec![0.0; n];
        }
        (0..n)
            .map(|i| {
                cluster
                    .reliable_send(i, 0, distincts[i] * SHUFFLE_ENTRY_BYTES)
                    .expect("tail members are live")
                    .cost
            })
            .collect()
    }

    /// The seed shuffle/reduce/collect tail: every phase runs on the
    /// calling thread, one member after another. This is the in-run
    /// referee the parallel tail is compared against bit-for-bit.
    fn tail_sequential(
        &self,
        cluster: &mut GridCluster,
        members: &[NodeId],
        mut bucketed: Vec<Vec<OwnerBucket>>,
        distincts: &[u64],
        local_factor: f64,
    ) -> TailOutput {
        let n = members.len();
        let backend = cluster.cfg.backend.clone();

        // Phase 3: shuffle. Keys move to their partition owner (the owner
        // was fixed at emit time — no re-hash here). The *owner* pays the
        // per-key merge/accounting cost (distinct/n keys each, in
        // parallel): Hazelcast 3.2's young MR does a supervisor round-trip
        // per keyed result — the Table 5.3 collapse when a single-node job
        // (no shuffle at all) becomes distributed.
        //
        // BTreeMap, not HashMap: phase 4 accumulates f64 costs while
        // iterating this map, and f64 addition is order-sensitive — sorted
        // iteration keeps sim_time_s bit-identical across runs (the
        // parallel engine's determinism contract is asserted exactly).
        let wires = Self::shuffle_wires(cluster, distincts);
        let mut grouped: Vec<BTreeMap<String, Vec<i64>>> = vec![BTreeMap::new(); n];
        for (i, m) in members.iter().enumerate() {
            if n > 1 {
                cluster.advance_busy(*m, wires[i]);
            }
            for (owner, bucket) in bucketed[i].drain(..).enumerate() {
                for (k, v) in bucket {
                    grouped[owner].entry(k).or_default().push(v);
                }
            }
        }
        if n > 1 {
            for (i, m) in members.iter().enumerate() {
                let gc = cluster.gc_factor(*m);
                let merge_cpu = grouped[i].len() as f64 * backend.mr_shuffle_per_key;
                cluster.advance_busy(*m, merge_cpu * gc);
            }
        }
        cluster.barrier();

        // Phase 4: reduce. `grouped` is owned, so keys move into the
        // result map — no per-key clone.
        let mut final_counts: BTreeMap<String, i64> = BTreeMap::new();
        let mut reduce_invocations: u64 = 0;
        for (i, m) in members.iter().enumerate() {
            let gc = cluster.gc_factor(*m);
            let mut cost = 0.0;
            for (k, vals) in std::mem::take(&mut grouped[i]) {
                cost += backend.mr_reduce_overhead + vals.len() as f64 * REDUCE_VALUE_CPU_COST;
                reduce_invocations += 1;
                let folded = self.reducer.reduce(&k, &vals);
                final_counts.insert(k, folded);
            }
            if self.job.verbose {
                cost *= 1.15;
            }
            cluster.advance_busy(*m, cost * local_factor * gc);
        }
        cluster.barrier();

        let total_count: i64 = final_counts.values().sum();
        let top_words = top_n(&final_counts, 10);
        (reduce_invocations, total_count, top_words)
    }

    /// The owner-partitioned parallel tail: shuffle is a bucket hand-off,
    /// each owner's grouping + fold run inside the two-phase shard
    /// machinery on real OS threads (keys moved, never cloned), and
    /// collect k-way-merges the per-owner sorted results.
    ///
    /// Bit-exactness with [`MapReduceEngine::tail_sequential`] is by
    /// construction: per member, the same `advance_busy` values are applied
    /// in the same order around the same two barriers, and the shards run
    /// through [`GridCluster::execute_sharded_silent`], which adds no
    /// dispatch or completion-sync charges of its own.
    fn tail_parallel(
        &self,
        cluster: &mut GridCluster,
        members: &[NodeId],
        bucketed: Vec<Vec<OwnerBucket>>,
        distincts: &[u64],
        local_factor: f64,
    ) -> TailOutput {
        let n = members.len();
        let multi = n > 1;
        let per_key = cluster.cfg.backend.mr_shuffle_per_key;
        let reduce_overhead = cluster.cfg.backend.mr_reduce_overhead;
        let verbose = self.job.verbose;
        let reducer = self.reducer;

        // Phase 3a (master): hand each owner its buckets, source-ordered —
        // per-key value order stays "source member ascending", exactly the
        // order the sequential drain produces.
        let mut owner_inputs: Vec<Vec<OwnerBucket>> = Vec::new();
        owner_inputs.resize_with(n, || Vec::with_capacity(n));
        for source in bucketed {
            for (owner, bucket) in source.into_iter().enumerate() {
                owner_inputs[owner].push(bucket);
            }
        }
        // Wire costs in member order through the reliable layer, so the
        // net model's counters, sequence numbers and fault draws advance
        // in the same sequence as the sequential referee's.
        let wires = Self::shuffle_wires(cluster, distincts);

        // Phase 3b (threads): each owner charges its shuffle costs and
        // groups its keys. The `Mutex<Option<..>>` cells exist only to move
        // each owner's input into its body (one uncontended lock per
        // member).
        let handoff: Vec<Mutex<Option<Vec<OwnerBucket>>>> = owner_inputs
            .into_iter()
            .map(|x| Mutex::new(Some(x)))
            .collect();
        let grouped: Vec<BTreeMap<String, Vec<i64>>> = cluster.execute_sharded_silent(|ctx| {
            let i = ctx.offset();
            if multi {
                ctx.advance_busy(wires[i]);
            }
            let sources = handoff[i].lock().unwrap().take().expect("one owner per shard");
            let mut mine: BTreeMap<String, Vec<i64>> = BTreeMap::new();
            for bucket in sources {
                for (k, v) in bucket {
                    mine.entry(k).or_default().push(v);
                }
            }
            if multi {
                let gc = ctx.gc_factor();
                ctx.advance_busy(mine.len() as f64 * per_key * gc);
            }
            mine
        });
        cluster.barrier();

        // Phase 4 (threads): each owner folds its keys, accumulating cost
        // in sorted-key order — the sequential referee's exact f64
        // sequence — and returns its results as a key-sorted run.
        let handoff: Vec<Mutex<Option<BTreeMap<String, Vec<i64>>>>> =
            grouped.into_iter().map(|g| Mutex::new(Some(g))).collect();
        let folded: Vec<(OwnerBucket, u64)> = cluster.execute_sharded_silent(|ctx| {
            let mine = handoff[ctx.offset()].lock().unwrap().take().expect("one owner per shard");
            let gc = ctx.gc_factor();
            let mut cost = 0.0;
            let mut run: OwnerBucket = Vec::with_capacity(mine.len());
            let mut invocations: u64 = 0;
            for (k, vals) in mine {
                cost += reduce_overhead + vals.len() as f64 * REDUCE_VALUE_CPU_COST;
                invocations += 1;
                let out = reducer.reduce(&k, &vals);
                run.push((k, out));
            }
            if verbose {
                cost *= 1.15;
            }
            ctx.advance_busy(cost * local_factor * gc);
            (run, invocations)
        });
        cluster.barrier();

        // Phase 5a (master): k-way merge of the per-owner sorted runs
        // replaces the sequential tail's global BTreeMap insert stream.
        let mut reduce_invocations: u64 = 0;
        let mut runs: Vec<OwnerBucket> = Vec::with_capacity(n);
        for (run, invocations) in folded {
            reduce_invocations += invocations;
            runs.push(run);
        }
        let merged = merge_sorted_counts(runs);
        debug_assert_eq!(merged.len() as u64, reduce_invocations);
        let total_count: i64 = merged.iter().map(|(_, c)| *c).sum();
        let top_words = top_n_pairs(merged.iter().map(|(k, c)| (k.as_str(), *c)), 10);
        (reduce_invocations, total_count, top_words)
    }

    fn release_on_err(
        &self,
        cluster: &mut GridCluster,
        members: &[NodeId],
        reserved: &[u64],
        e: C2SError,
    ) -> C2SError {
        for (i, m) in members.iter().enumerate() {
            cluster.release_scratch(*m, reserved.get(i).copied().unwrap_or(0));
        }
        e
    }

    /// Simulate a member joining while the job runs. Hazelcast 3.2 crashed
    /// the running job (hazelcast#2354, §5.2.2: "a newly joined instance
    /// not knowing the supervisor of the job"); Infinispan migrates
    /// partitions and continues.
    pub fn simulate_midjob_join(&self, cluster: &mut GridCluster) -> Result<NodeId> {
        if cluster.cfg.backend.join_crashes_running_mr {
            return Err(C2SError::MapReduce(
                "newly joined instance crashed the running MapReduce job \
                 (missing supervisor null-check — hazelcast#2354). \
                 Work-around: join all Initiators before starting the master."
                    .into(),
            ));
        }
        Ok(cluster.join())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::backend::BackendProfile;
    use crate::grid::cluster::GridConfig;
    use crate::grid::serialize::InMemoryFormat;
    use crate::mapreduce::corpus::CorpusConfig;
    use crate::mapreduce::wordcount::{WordCountMapper, WordCountReducer};

    fn grid(backend: BackendProfile, n: usize, heap_mb: u64) -> GridCluster {
        GridCluster::with_members(
            GridConfig {
                backend,
                in_memory_format: InMemoryFormat::Object, // §4.1.2: MR uses OBJECT
                node_heap_bytes: heap_mb * 1024 * 1024,
                ..GridConfig::default()
            },
            n,
        )
    }

    fn small_corpus(files: usize, lines: usize) -> Corpus {
        Corpus::new(CorpusConfig {
            files,
            distinct_files: files.min(3),
            lines_per_file: lines,
            ..CorpusConfig::default()
        })
    }

    fn engine(corpus: Corpus) -> (WordCountMapper, WordCountReducer, Corpus) {
        (WordCountMapper, WordCountReducer, corpus)
    }

    #[test]
    fn word_count_is_correct_and_conserved() {
        let (m, r, c) = engine(small_corpus(3, 200));
        let eng = MapReduceEngine::new(c, JobConfig::default(), &m, &r);
        let mut cluster = grid(BackendProfile::infinispan_like(), 2, 64);
        let res = eng.run(&mut cluster).unwrap();
        assert_eq!(res.map_invocations, 3);
        assert!(res.reduce_invocations > 100);
        assert!(res.is_conserved(), "Σcounts == tokens");
        assert_eq!(res.emitted_pairs, 3 * 200 * 12);
        assert!(!res.top_words.is_empty());
    }

    #[test]
    fn same_answer_on_any_cluster_size() {
        // §3.1.1: "the output is consistent as if simulating in a single
        // instance"
        let (m, r, c) = engine(small_corpus(3, 150));
        let run = |n: usize| {
            let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
            let mut cluster = grid(BackendProfile::infinispan_like(), n, 64);
            eng.run(&mut cluster).unwrap()
        };
        let r1 = run(1);
        let r4 = run(4);
        assert_eq!(r1.reduce_invocations, r4.reduce_invocations);
        assert_eq!(r1.total_count, r4.total_count);
        assert_eq!(r1.top_words, r4.top_words);
    }

    #[test]
    fn infinispan_much_faster_than_hazelcast_single_node() {
        // Fig 5.9: "Infinispan outperforming Hazelcast by 10 to 100 folds"
        let (m, r, c) = engine(small_corpus(3, 1000));
        let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
        let mut hz = grid(BackendProfile::hazelcast_like(), 1, 64);
        let t_hz = eng.run(&mut hz).unwrap().sim_time_s;
        let eng = MapReduceEngine::new(c, JobConfig::default(), &m, &r);
        let mut inf = grid(BackendProfile::infinispan_like(), 1, 64);
        let t_inf = eng.run(&mut inf).unwrap().sim_time_s;
        let fold = t_hz / t_inf;
        assert!(fold > 10.0, "expected ≥10×, got {fold:.1}× ({t_hz} vs {t_inf})");
    }

    #[test]
    fn hazelcast_two_instances_slower_than_one() {
        // Table 5.3: 416s on 1 instance → 2580s on 2
        let (m, r, c) = engine(small_corpus(3, 1500));
        let run = |n: usize| {
            let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
            let mut cluster = grid(BackendProfile::hazelcast_like(), n, 64);
            eng.run(&mut cluster).unwrap().sim_time_s
        };
        let t1 = run(1);
        let t2 = run(2);
        let t4 = run(4);
        assert!(t2 > t1 * 2.0, "distribution collapse: {t1} -> {t2}");
        assert!(t4 < t2, "then improves with more instances: {t4} vs {t2}");
    }

    #[test]
    fn infinispan_scales_positively() {
        // needs a job big enough that map+reduce work dominates the
        // distribution overheads (Fig 5.10 uses 159k reduce invocations)
        let (m, r, c) = engine(small_corpus(12, 4000));
        let run = |n: usize| {
            let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
            let mut cluster = grid(BackendProfile::infinispan_like(), n, 64);
            eng.run(&mut cluster).unwrap().sim_time_s
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(t4 < t1, "Fig 5.10 positive scalability: {t1} -> {t4}");
    }

    #[test]
    fn oom_on_one_node_fixed_by_more_nodes() {
        // Fig 5.10: large jobs fail on one instance, run on more
        let (m, r, c) = engine(small_corpus(12, 30_000));
        let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
        let mut one = grid(BackendProfile::infinispan_like(), 1, 16);
        let err = eng.run(&mut one).expect_err("must OOM on one small node");
        assert!(err.is_oom(), "{err}");
        let eng = MapReduceEngine::new(c, JobConfig::default(), &m, &r);
        let mut four = grid(BackendProfile::infinispan_like(), 4, 16);
        let res = eng.run(&mut four).unwrap();
        assert!(res.is_conserved());
    }

    #[test]
    fn verbose_mode_is_slower() {
        let (m, r, c) = engine(small_corpus(3, 500));
        let eng = MapReduceEngine::new(c.clone(), JobConfig::default(), &m, &r);
        let mut a = grid(BackendProfile::infinispan_like(), 2, 64);
        let quiet = eng.run(&mut a).unwrap().sim_time_s;
        let eng = MapReduceEngine::new(
            c,
            JobConfig {
                verbose: true,
                ..JobConfig::default()
            },
            &m,
            &r,
        );
        let mut b = grid(BackendProfile::infinispan_like(), 2, 64);
        let verbose = eng.run(&mut b).unwrap().sim_time_s;
        assert!(verbose > quiet, "{verbose} vs {quiet}");
    }

    #[test]
    fn midjob_join_crashes_hazelcast_not_infinispan() {
        let (m, r, c) = engine(small_corpus(3, 100));
        let eng = MapReduceEngine::new(c, JobConfig::default(), &m, &r);
        let mut hz = grid(BackendProfile::hazelcast_like(), 2, 64);
        assert!(eng.simulate_midjob_join(&mut hz).is_err());
        let mut inf = grid(BackendProfile::infinispan_like(), 2, 64);
        let joined = eng.simulate_midjob_join(&mut inf).unwrap();
        assert_eq!(inf.size(), 3);
        assert!(inf.members().contains(&joined));
    }
}

#[cfg(test)]
mod split_brain_tests {
    use super::*;
    use crate::grid::backend::BackendProfile;
    use crate::grid::cluster::GridConfig;
    use crate::grid::serialize::InMemoryFormat;
    use crate::mapreduce::corpus::CorpusConfig;
    use crate::mapreduce::wordcount::{WordCountMapper, WordCountReducer};

    fn grid(backend: BackendProfile, n: usize) -> GridCluster {
        GridCluster::with_members(
            GridConfig {
                backend,
                in_memory_format: InMemoryFormat::Object,
                node_heap_bytes: 64 * 1024 * 1024,
                ..GridConfig::default()
            },
            n,
        )
    }

    fn long_corpus() -> Corpus {
        Corpus::new(CorpusConfig {
            lines_per_file: 3000, // distributed hz job runs well past 600s
            ..CorpusConfig::default()
        })
    }

    #[test]
    fn long_hazelcast_jobs_split_brain() {
        let (m, r) = (WordCountMapper, WordCountReducer);
        let eng = MapReduceEngine::new(long_corpus(), JobConfig::default(), &m, &r);
        let mut hz = grid(BackendProfile::hazelcast_like(), 3);
        let res = eng.run(&mut hz).unwrap();
        assert!(res.sim_time_s > 600.0, "needs a long job: {}", res.sim_time_s);
        assert!(
            res.split_brain_events >= 1,
            "hazelcast#2359: long heavy jobs split-brain"
        );
        assert!(hz.metrics.counter("cluster.split_brain") >= 1);
        assert!(res.is_conserved(), "synchronous backups keep results intact");
    }

    #[test]
    fn infinispan_never_split_brains() {
        let (m, r) = (WordCountMapper, WordCountReducer);
        let eng = MapReduceEngine::new(long_corpus(), JobConfig::default(), &m, &r);
        let mut inf = grid(BackendProfile::infinispan_like(), 3);
        let res = eng.run(&mut inf).unwrap();
        assert_eq!(res.split_brain_events, 0);
    }

    #[test]
    fn short_jobs_are_safe_on_hazelcast() {
        // the paper's work-around: keep Hazelcast MR jobs short
        let (m, r) = (WordCountMapper, WordCountReducer);
        let corpus = Corpus::new(CorpusConfig {
            lines_per_file: 100,
            ..CorpusConfig::default()
        });
        let eng = MapReduceEngine::new(corpus, JobConfig::default(), &m, &r);
        let mut hz = grid(BackendProfile::hazelcast_like(), 3);
        let res = eng.run(&mut hz).unwrap();
        assert_eq!(res.split_brain_events, 0);
    }
}

#[cfg(test)]
mod fault_tests {
    use super::*;
    use crate::faults::{FaultPlan, SpeculativeExecution};
    use crate::grid::backend::BackendProfile;
    use crate::grid::cluster::GridConfig;
    use crate::grid::serialize::InMemoryFormat;
    use crate::mapreduce::corpus::CorpusConfig;
    use crate::mapreduce::wordcount::{WordCountMapper, WordCountReducer};

    fn grid(backend: BackendProfile, n: usize) -> GridCluster {
        GridCluster::with_members(
            GridConfig {
                backend,
                in_memory_format: InMemoryFormat::Object,
                node_heap_bytes: 64 * 1024 * 1024,
                ..GridConfig::default()
            },
            n,
        )
    }

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig {
            files: 3,
            distinct_files: 3,
            lines_per_file: 200,
            ..CorpusConfig::default()
        })
    }

    fn run_with(plan: Option<FaultPlan>, n: usize) -> JobResult {
        let (m, r) = (WordCountMapper, WordCountReducer);
        // small chunks so every member (and so any fault victim) has work
        let job = JobConfig {
            chunk_lines: 50,
            ..JobConfig::default()
        };
        let mut eng = MapReduceEngine::new(corpus(), job, &m, &r);
        if let Some(p) = plan {
            eng = eng.with_fault_plan(p);
        }
        let mut cluster = grid(BackendProfile::infinispan_like(), n);
        eng.run(&mut cluster).unwrap()
    }

    #[test]
    fn crash_reexecutes_lost_chunks_and_preserves_results() {
        let clean = run_with(None, 3);
        let plan = FaultPlan {
            member_crash_at: Some(0.1),
            member_rejoin_at: Some(2.0),
            ..FaultPlan::default()
        };
        let faulted = run_with(Some(plan), 3);
        // the referee contract: data results are bit-identical
        assert_eq!(faulted.total_count, clean.total_count);
        assert_eq!(faulted.emitted_pairs, clean.emitted_pairs);
        assert_eq!(faulted.top_words, clean.top_words);
        assert_eq!(faulted.reduce_invocations, clean.reduce_invocations);
        assert!(faulted.is_conserved());
        // recovery really happened and was logged
        assert!(faulted.tasks_reexecuted > 0, "{faulted:?}");
        assert!(faulted.sim_time_s > clean.sim_time_s, "recovery costs time");
        let kinds: Vec<_> = faulted.fault_events.iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&FaultKind::Crash));
        assert!(kinds.contains(&FaultKind::Reexecution));
        assert!(kinds.contains(&FaultKind::Rejoin));
        assert!(clean.fault_events.is_empty() && clean.tasks_reexecuted == 0);
    }

    #[test]
    fn straggler_skew_stretches_time_not_results() {
        let clean = run_with(None, 4);
        let plan = FaultPlan {
            slow_member_skew: 8.0,
            ..FaultPlan::default()
        };
        let skewed = run_with(Some(plan), 4);
        assert_eq!(skewed.total_count, clean.total_count);
        assert_eq!(skewed.top_words, clean.top_words);
        assert!(skewed.sim_time_s > clean.sim_time_s, "straggler must drag the barrier");
        assert!(skewed
            .fault_events
            .iter()
            .any(|e| e.kind == FaultKind::Straggler));
    }

    #[test]
    fn speculative_backup_wins_against_heavy_skew() {
        let base = FaultPlan {
            slow_member_skew: 8.0,
            ..FaultPlan::default()
        };
        let off = run_with(Some(base.clone()), 4);
        let on = run_with(
            Some(FaultPlan {
                speculative: SpeculativeExecution::On,
                ..base
            }),
            4,
        );
        // on/off parity on data, first-result-wins on time
        assert_eq!(on.total_count, off.total_count);
        assert_eq!(on.emitted_pairs, off.emitted_pairs);
        assert_eq!(on.top_words, off.top_words);
        assert_eq!(on.reduce_invocations, off.reduce_invocations);
        assert!(
            on.sim_time_s <= off.sim_time_s,
            "a backup can only help: {} vs {}",
            on.sim_time_s,
            off.sim_time_s
        );
        // an 8x skew on idle-ish peers must lose the race to a backup
        assert!(on.speculative_wins > 0, "{:?}", on.fault_events);
        assert!(on
            .fault_events
            .iter()
            .any(|e| e.kind == FaultKind::SpeculativeWin));
        assert_eq!(off.speculative_wins, 0);
    }

    #[test]
    fn same_seed_same_fault_log() {
        let plan = FaultPlan {
            member_crash_at: Some(0.5),
            slow_member_skew: 3.0,
            speculative: SpeculativeExecution::On,
            ..FaultPlan::default()
        };
        let a = run_with(Some(plan.clone()), 4);
        let b = run_with(Some(plan), 4);
        let fa: Vec<String> = a.fault_events.iter().map(|e| e.fingerprint()).collect();
        let fb: Vec<String> = b.fault_events.iter().map(|e| e.fingerprint()).collect();
        assert_eq!(fa, fb);
        assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits());
    }

    #[test]
    fn link_faults_move_clocks_never_data() {
        let clean = run_with(None, 3);
        let plan = FaultPlan {
            link_drop_prob: 0.2,
            link_dup_prob: 1.0, // every delivery duplicated → dedup must fire
            link_jitter: 0.001,
            link_partition_at: Some(0.0001),
            link_heal_at: Some(2.0),
            delivery_retry_budget: 16,
            delivery_backoff_base: 0.05,
            ..FaultPlan::default()
        };
        let faulted = run_with(Some(plan.clone()), 3);
        // referee contract, now extended to the transport: data identical
        assert_eq!(faulted.total_count, clean.total_count);
        assert_eq!(faulted.emitted_pairs, clean.emitted_pairs);
        assert_eq!(faulted.top_words, clean.top_words);
        assert_eq!(faulted.reduce_invocations, clean.reduce_invocations);
        assert!(faulted.is_conserved());
        // but the partitioned shuffle really paid the backoff ladder
        assert!(faulted.sim_time_s > clean.sim_time_s, "retries cost time");
        assert!(faulted.net_retries > 0, "{faulted:?}");
        assert!(faulted.net_dropped > 0);
        assert!(faulted.net_deduplicated > 0);
        assert_eq!(faulted.split_brain_events, 1, "one partition, one merge");
        let kinds: Vec<_> = faulted.fault_events.iter().map(|e| e.kind).collect();
        for k in [
            FaultKind::LinkPartition,
            FaultKind::SplitBrain,
            FaultKind::LinkHeal,
            FaultKind::SplitBrainMerge,
            FaultKind::LinkDrop,
            FaultKind::LinkDup,
        ] {
            assert!(kinds.contains(&k), "missing {k} in {kinds:?}");
        }
        assert_eq!(clean.net_retries + clean.net_dropped + clean.net_deduplicated, 0);
        // same seed → bit-identical log and clocks
        let again = run_with(Some(plan), 3);
        let fa: Vec<String> = faulted.fault_events.iter().map(|e| e.fingerprint()).collect();
        let fb: Vec<String> = again.fault_events.iter().map(|e| e.fingerprint()).collect();
        assert_eq!(fa, fb);
        assert_eq!(faulted.sim_time_s.to_bits(), again.sim_time_s.to_bits());
    }

    #[test]
    fn single_member_cluster_survives_a_plan() {
        // nobody to crash, nobody to back up — the plan degrades to skew
        // on the only member and results stay intact
        let clean = run_with(None, 1);
        let plan = FaultPlan {
            member_crash_at: Some(0.5),
            slow_member_skew: 2.0,
            speculative: SpeculativeExecution::On,
            ..FaultPlan::default()
        };
        let faulted = run_with(Some(plan), 1);
        assert_eq!(faulted.total_count, clean.total_count);
        assert_eq!(faulted.top_words, clean.top_words);
        assert_eq!(faulted.tasks_reexecuted, 0, "no victim on 1 member");
        assert!(faulted.sim_time_s > clean.sim_time_s, "skew still applies");
    }
}
