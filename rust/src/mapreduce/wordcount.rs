//! The default word-count application (§4.2.2): "a simple word count
//! application, which lets the user visualize different MapReduce
//! scenarios. This default implementation can be replaced by custom
//! MapReduce implementations."

use crate::mapreduce::job::{Mapper, Reducer};

/// Tokenizes lines into lowercase words and emits `(word, 1)`.
#[derive(Debug, Default, Clone)]
pub struct WordCountMapper;

impl Mapper for WordCountMapper {
    fn map(&self, _file: usize, _line: usize, value: &str, emit: &mut dyn FnMut(String, i64)) {
        for token in value.split_whitespace() {
            // single-pass normalize: filter to alphanumerics + lowercase
            let mut w = String::with_capacity(token.len());
            for c in token.chars() {
                if c.is_alphanumeric() {
                    for lc in c.to_lowercase() {
                        w.push(lc);
                    }
                }
            }
            if !w.is_empty() {
                emit(w, 1);
            }
        }
    }
}

/// Sums the counts of one word.
#[derive(Debug, Default, Clone)]
pub struct WordCountReducer;

impl Reducer for WordCountReducer {
    fn reduce(&self, _key: &str, values: &[i64]) -> i64 {
        values.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapper_tokenizes_and_normalizes() {
        let m = WordCountMapper;
        let mut out = Vec::new();
        m.map(0, 0, "Hello, hello WORLD!  w42", &mut |k, v| out.push((k, v)));
        assert_eq!(
            out,
            vec![
                ("hello".to_string(), 1),
                ("hello".to_string(), 1),
                ("world".to_string(), 1),
                ("w42".to_string(), 1),
            ]
        );
    }

    #[test]
    fn mapper_skips_punctuation_only() {
        let m = WordCountMapper;
        let mut out = Vec::new();
        m.map(0, 0, "... --- !!!", &mut |k, v| out.push((k, v)));
        assert!(out.is_empty());
    }

    #[test]
    fn reducer_sums() {
        let r = WordCountReducer;
        assert_eq!(r.reduce("w", &[1, 1, 3]), 5);
        assert_eq!(r.reduce("w", &[]), 0);
    }
}
