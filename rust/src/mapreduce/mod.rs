//! The MapReduce simulation layer (§3.4.2, §4.2): a real word-count
//! MapReduce engine running over the grid substrate, with the two backend
//! profiles the paper benchmarks against each other.
//!
//! * [`corpus`] — synthetic USENET-like corpus (lazy, deterministic).
//! * [`job`] — `Mapper`/`Reducer` traits, job config/results.
//! * [`wordcount`] — the default application (§4.2.2).
//! * [`engine`] — the shared supervisor/engine (map → shuffle → reduce).
//! * [`hz_engine`] / [`inf_engine`] — the two implementations
//!   (`HzMapReduceSimulator` / `InfMapReduceSimulator`).

pub mod corpus;
pub mod engine;
pub mod hz_engine;
pub mod inf_engine;
pub mod job;
pub mod wordcount;

/// Default executor worker count for MapReduce runs: every available core
/// (map-phase tokenization is real CPU work; virtual-time results are
/// identical at any worker count).
pub fn default_workers() -> usize {
    crate::grid::parallel::resolve_workers(0)
}

pub use corpus::{Corpus, CorpusConfig};
pub use engine::MapReduceEngine;
pub use hz_engine::{run_hz_wordcount, run_hz_wordcount_faulted, run_hz_wordcount_with_workers};
pub use inf_engine::{run_inf_wordcount, run_inf_wordcount_faulted, run_inf_wordcount_with_workers};
pub use job::{JobConfig, JobResult, Mapper, MrPipeline, Reducer};
