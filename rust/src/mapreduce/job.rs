//! MapReduce job abstractions (§4.2.2): user-replaceable `Mapper` and
//! `Reducer` traits plus job configuration and results.

use std::collections::BTreeMap;

use crate::faults::FaultEvent;

/// Emits intermediate `(key, value)` pairs from one input record.
///
/// `Sync` is a supertrait: the engine's map phase runs member tasks on
/// real OS threads (the two-phase parallel executor), sharing the mapper
/// by reference. Stateless unit-struct mappers satisfy this for free.
pub trait Mapper: Sync {
    /// Map one record (a corpus line) to zero or more `(word, count)`
    /// pairs via `emit`.
    fn map(&self, file: usize, line: usize, value: &str, emit: &mut dyn FnMut(String, i64));
}

/// Folds all values of one key. `Sync` for the same reason as [`Mapper`].
pub trait Reducer: Sync {
    /// Reduce the accumulated values of `key`.
    fn reduce(&self, key: &str, values: &[i64]) -> i64;
}

/// Which shuffle/reduce/collect implementation the engine runs
/// (`mrPipeline` in `cloud2sim.properties`).
///
/// Both pipelines produce **bitwise-identical** virtual times and results
/// (the parallel engine's determinism contract, fuzzed by
/// `rust/tests/props_mr.rs`); they differ only in wall-clock behaviour.
/// `Sequential` is the seed implementation and doubles as the in-run
/// referee for the `megascale_wordcount` scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MrPipeline {
    /// Seed behaviour: shuffle, reduce and collect run on the calling
    /// thread, one member after another.
    Sequential,
    /// Owner-partitioned hot path: mappers emit into per-owner buckets,
    /// each owner groups and folds its keys inside the two-phase parallel
    /// executor, and collect k-way-merges the per-owner sorted results.
    #[default]
    Parallel,
}

impl std::str::FromStr for MrPipeline {
    type Err = String;

    /// Parse the `mrPipeline` property / `--pipeline` flag value —
    /// delegates to the unified [`crate::config::ConfigKnob`] parser, so
    /// variants, case-insensitivity and the error shape come from the
    /// same place as every other knob.
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        crate::config::ConfigKnob::parse_knob(s)
    }
}

/// Job parameters (`cloud2sim.properties` MapReduce section, §4.2.3).
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Lines processed per supervisor chunk.
    pub chunk_lines: usize,
    /// Verbose mode: per-instance progress accounting (§3.4.2) — slower.
    pub verbose: bool,
    /// Shuffle/reduce/collect implementation (`mrPipeline`).
    pub pipeline: MrPipeline,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            chunk_lines: 1000,
            verbose: false,
            pipeline: MrPipeline::default(),
        }
    }
}

/// Result of one MapReduce job run.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// `map()` invocations (= input files).
    pub map_invocations: u64,
    /// `reduce()` invocations (= distinct keys).
    pub reduce_invocations: u64,
    /// Virtual execution time (s) — the paper's measured quantity.
    pub sim_time_s: f64,
    /// Total emitted pairs (tokens for word count).
    pub emitted_pairs: u64,
    /// Final aggregate (word → count), truncated to the top entries for
    /// reporting; the full count is `reduce_invocations`.
    pub top_words: Vec<(String, i64)>,
    /// Sum over all counts (equals emitted pairs for word count).
    pub total_count: i64,
    /// Instances that participated.
    pub nodes: usize,
    /// Peak per-node heap used (bytes).
    pub peak_heap: u64,
    /// Split-brain incidents observed during the job (§4.3.3: long heavy
    /// Hazelcast jobs saw instances leave and the cluster split/merge —
    /// hazelcast#2359 — "limiting the usability ... to shorter jobs").
    pub split_brain_events: u32,
    /// Map chunks lost to a member crash and re-executed on survivors
    /// (0 without a fault plan).
    pub tasks_reexecuted: u64,
    /// Straggler chunks whose speculative backup finished first
    /// (0 unless `speculativeExecution=on`).
    pub speculative_wins: u64,
    /// Deterministic fault log (empty without a fault plan) — same-seed
    /// runs must produce bit-identical logs at every worker count.
    pub fault_events: Vec<FaultEvent>,
    /// Network messages sent over the whole run (Fig 5.8-style
    /// distribution statistics, surfaced as BENCH extras).
    pub net_messages: u64,
    /// Network payload bytes moved over the whole run.
    pub net_bytes: u64,
    /// Reliable-delivery ack-timeout retries (0 without link faults).
    pub net_retries: u64,
    /// Delivery attempts lost to drops or the partition window.
    pub net_dropped: u64,
    /// Duplicated deliveries discarded by receiver-side dedup.
    pub net_deduplicated: u64,
}

impl JobResult {
    /// Cross-check invariant for word count: Σ counts == emitted tokens.
    pub fn is_conserved(&self) -> bool {
        self.total_count as u64 == self.emitted_pairs
    }
}

/// Deterministically pick the top-`n` entries of a count map (ties by key).
pub fn top_n(counts: &BTreeMap<String, i64>, n: usize) -> Vec<(String, i64)> {
    top_n_pairs(counts.iter().map(|(k, c)| (k.as_str(), *c)), n)
}

/// Streaming top-`n` selection over `(key, count)` pairs under the count
/// comparator (count descending, ties by key ascending). Keys must be
/// distinct; the comparator is then a total order, so the selection is
/// independent of the input order — both MapReduce pipelines share this
/// one implementation, which is what makes their `top_words` comparable
/// bit-for-bit.
pub fn top_n_pairs<'a>(
    pairs: impl Iterator<Item = (&'a str, i64)>,
    n: usize,
) -> Vec<(String, i64)> {
    let mut best: Vec<(String, i64)> = Vec::with_capacity(n.saturating_add(1).min(64));
    for (k, c) in pairs {
        let outranks = |a: &(String, i64)| c > a.1 || (c == a.1 && k < a.0.as_str());
        if best.len() < n {
            let pos = best.partition_point(|a| !outranks(a));
            best.insert(pos, (k.to_string(), c));
        } else if n > 0 && outranks(&best[n - 1]) {
            let pos = best.partition_point(|a| !outranks(a));
            best.insert(pos, (k.to_string(), c));
            best.truncate(n);
        }
    }
    best
}

/// K-way-merge per-owner key-sorted `(key, count)` runs into one globally
/// key-sorted stream — the parallel pipeline's collect phase. Owners
/// partition the key space, so the runs are pairwise disjoint and the
/// merged stream equals the sequential pipeline's global `BTreeMap`
/// iteration order. Strings are moved, never cloned.
pub fn merge_sorted_counts(runs: Vec<Vec<(String, i64)>>) -> Vec<(String, i64)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let total: usize = runs.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    // heap of (next key, count, source run): pop-min yields global order
    let mut iters: Vec<std::vec::IntoIter<(String, i64)>> =
        runs.into_iter().map(Vec::into_iter).collect();
    let mut heap: BinaryHeap<Reverse<(String, i64, usize)>> = BinaryHeap::new();
    for (r, it) in iters.iter_mut().enumerate() {
        if let Some((k, c)) = it.next() {
            heap.push(Reverse((k, c, r)));
        }
    }
    while let Some(Reverse((k, c, r))) = heap.pop() {
        if let Some((prev, _)) = out.last() {
            debug_assert!(*prev < k, "owner runs must be sorted and pairwise disjoint");
        }
        out.push((k, c));
        if let Some((nk, nc)) = iters[r].next() {
            heap.push(Reverse((nk, nc, r)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_n_orders_and_truncates() {
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 5);
        m.insert("b".to_string(), 9);
        m.insert("c".to_string(), 5);
        let t = top_n(&m, 2);
        assert_eq!(t, vec![("b".to_string(), 9), ("a".to_string(), 5)]);
    }

    #[test]
    fn top_n_pairs_matches_sort_based_selection() {
        // streaming selection must equal "sort everything, truncate"
        let pairs = vec![("m", 4i64), ("a", 7), ("z", 7), ("q", 1), ("b", 4), ("c", 9)];
        let mut reference: Vec<(String, i64)> =
            pairs.iter().map(|(k, c)| (k.to_string(), *c)).collect();
        reference.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for n in 0..=pairs.len() + 1 {
            let mut want = reference.clone();
            want.truncate(n);
            let got = top_n_pairs(pairs.iter().map(|(k, c)| (*k, *c)), n);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn merge_sorted_counts_interleaves_disjoint_runs() {
        let runs = vec![
            vec![("a".to_string(), 1i64), ("d".to_string(), 4)],
            vec![("b".to_string(), 2), ("e".to_string(), 5)],
            vec![],
            vec![("c".to_string(), 3)],
        ];
        let merged = merge_sorted_counts(runs);
        let keys: Vec<&str> = merged.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "b", "c", "d", "e"]);
        assert_eq!(merged.iter().map(|(_, c)| c).sum::<i64>(), 15);
    }

    #[test]
    fn pipeline_default_is_parallel() {
        assert_eq!(JobConfig::default().pipeline, MrPipeline::Parallel);
    }

    #[test]
    fn pipeline_parses_case_insensitively() {
        assert_eq!("sequential".parse(), Ok(MrPipeline::Sequential));
        assert_eq!("Parallel".parse(), Ok(MrPipeline::Parallel));
        assert_eq!("SEQUENTIAL".parse(), Ok(MrPipeline::Sequential));
        assert!("threaded".parse::<MrPipeline>().is_err());
    }

    #[test]
    fn conservation_check() {
        let r = JobResult {
            map_invocations: 3,
            reduce_invocations: 10,
            sim_time_s: 1.0,
            emitted_pairs: 100,
            top_words: vec![],
            total_count: 100,
            nodes: 1,
            peak_heap: 0,
            split_brain_events: 0,
            tasks_reexecuted: 0,
            speculative_wins: 0,
            fault_events: vec![],
            net_messages: 0,
            net_bytes: 0,
            net_retries: 0,
            net_dropped: 0,
            net_deduplicated: 0,
        };
        assert!(r.is_conserved());
    }
}
