//! MapReduce job abstractions (§4.2.2): user-replaceable `Mapper` and
//! `Reducer` traits plus job configuration and results.

use std::collections::BTreeMap;

/// Emits intermediate `(key, value)` pairs from one input record.
///
/// `Sync` is a supertrait: the engine's map phase runs member tasks on
/// real OS threads (the two-phase parallel executor), sharing the mapper
/// by reference. Stateless unit-struct mappers satisfy this for free.
pub trait Mapper: Sync {
    /// Map one record (a corpus line) to zero or more `(word, count)`
    /// pairs via `emit`.
    fn map(&self, file: usize, line: usize, value: &str, emit: &mut dyn FnMut(String, i64));
}

/// Folds all values of one key. `Sync` for the same reason as [`Mapper`].
pub trait Reducer: Sync {
    /// Reduce the accumulated values of `key`.
    fn reduce(&self, key: &str, values: &[i64]) -> i64;
}

/// Job parameters (`cloud2sim.properties` MapReduce section, §4.2.3).
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Lines processed per supervisor chunk.
    pub chunk_lines: usize,
    /// Verbose mode: per-instance progress accounting (§3.4.2) — slower.
    pub verbose: bool,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            chunk_lines: 1000,
            verbose: false,
        }
    }
}

/// Result of one MapReduce job run.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// `map()` invocations (= input files).
    pub map_invocations: u64,
    /// `reduce()` invocations (= distinct keys).
    pub reduce_invocations: u64,
    /// Virtual execution time (s) — the paper's measured quantity.
    pub sim_time_s: f64,
    /// Total emitted pairs (tokens for word count).
    pub emitted_pairs: u64,
    /// Final aggregate (word → count), truncated to the top entries for
    /// reporting; the full count is `reduce_invocations`.
    pub top_words: Vec<(String, i64)>,
    /// Sum over all counts (equals emitted pairs for word count).
    pub total_count: i64,
    /// Instances that participated.
    pub nodes: usize,
    /// Peak per-node heap used (bytes).
    pub peak_heap: u64,
    /// Split-brain incidents observed during the job (§4.3.3: long heavy
    /// Hazelcast jobs saw instances leave and the cluster split/merge —
    /// hazelcast#2359 — "limiting the usability ... to shorter jobs").
    pub split_brain_events: u32,
}

impl JobResult {
    /// Cross-check invariant for word count: Σ counts == emitted tokens.
    pub fn is_conserved(&self) -> bool {
        self.total_count as u64 == self.emitted_pairs
    }
}

/// Deterministically pick the top-`n` entries of a count map (ties by key).
pub fn top_n(counts: &BTreeMap<String, i64>, n: usize) -> Vec<(String, i64)> {
    let mut v: Vec<(String, i64)> = counts.iter().map(|(k, c)| (k.clone(), *c)).collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(n);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_n_orders_and_truncates() {
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 5);
        m.insert("b".to_string(), 9);
        m.insert("c".to_string(), 5);
        let t = top_n(&m, 2);
        assert_eq!(t, vec![("b".to_string(), 9), ("a".to_string(), 5)]);
    }

    #[test]
    fn conservation_check() {
        let r = JobResult {
            map_invocations: 3,
            reduce_invocations: 10,
            sim_time_s: 1.0,
            emitted_pairs: 100,
            top_words: vec![],
            total_count: 100,
            nodes: 1,
            peak_heap: 0,
            split_brain_events: 0,
        };
        assert!(r.is_conserved());
    }
}
