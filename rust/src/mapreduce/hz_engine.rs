//! Hazelcast-profile MapReduce simulator (`HzMapReduceSimulator`, §4.2).
//!
//! Uses the Simulator–Initiator strategy: "One node starts the MapReduce
//! simulator, where other nodes start the Initiator class, which just
//! connects to the cluster and executes the logic fractions sent by the
//! master" (§5.2.2). The work-around for hazelcast#2354 is encoded here:
//! all Initiators must join *before* the supervisor starts.

use crate::error::Result;
use crate::faults::FaultPlan;
use crate::grid::backend::BackendProfile;
use crate::grid::cluster::{GridCluster, GridConfig};
use crate::grid::serialize::InMemoryFormat;
use crate::mapreduce::corpus::Corpus;
use crate::mapreduce::engine::MapReduceEngine;
use crate::mapreduce::job::{JobConfig, JobResult};
use crate::mapreduce::wordcount::{WordCountMapper, WordCountReducer};

/// Grid configuration for Hazelcast-profile MR: OBJECT in-memory format
/// ("Hazelcast is configured with OBJECT in-memory format for MapReduce
/// simulations. This eliminates most serialization costs", §4.1.2).
/// `workers` stays at the sequential default; the `run_hz_wordcount*`
/// entry points choose the executor worker count.
pub fn hz_mr_grid_config(node_heap_bytes: u64, seed: u64) -> GridConfig {
    GridConfig {
        backend: BackendProfile::hazelcast_like(),
        in_memory_format: InMemoryFormat::Object,
        node_heap_bytes,
        seed,
        ..GridConfig::default()
    }
}

/// Run the default word-count job on a Hazelcast-profile cluster of
/// `instances` members. `instances` may exceed physical nodes — the paper
/// ran "up to 2 Hazelcast instances ... from each of the nodes" (§5.2.2).
pub fn run_hz_wordcount(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
) -> Result<JobResult> {
    let workers = crate::mapreduce::default_workers();
    run_hz_wordcount_with_workers(corpus, job, instances, node_heap_bytes, workers)
}

/// [`run_hz_wordcount`] with an explicit executor worker count
/// (`workers = 1` forces the sequential engine; virtual-time results are
/// identical either way).
pub fn run_hz_wordcount_with_workers(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
    workers: usize,
) -> Result<JobResult> {
    run_hz_wordcount_faulted(
        corpus,
        job,
        instances,
        node_heap_bytes,
        workers,
        FaultPlan::default(),
    )
}

/// [`run_hz_wordcount_with_workers`] under a deterministic fault plan.
/// A no-op plan takes the exact fault-free code path, so the fault
/// scenarios can use the same entry point for headline and referee runs.
pub fn run_hz_wordcount_faulted(
    corpus: Corpus,
    job: JobConfig,
    instances: usize,
    node_heap_bytes: u64,
    workers: usize,
    plan: FaultPlan,
) -> Result<JobResult> {
    let mapper = WordCountMapper;
    let reducer = WordCountReducer;
    let engine = MapReduceEngine::new(corpus, job, &mapper, &reducer).with_fault_plan(plan);
    // work-around hazelcast#2354: form the whole cluster BEFORE the
    // supervisor starts (all Initiators first, master last)
    let mut cluster = GridCluster::with_members(
        GridConfig {
            workers: workers.max(1),
            ..hz_mr_grid_config(node_heap_bytes, 0xC10D ^ instances as u64)
        },
        instances,
    );
    engine.run(&mut cluster)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::corpus::CorpusConfig;

    #[test]
    fn hz_wordcount_runs() {
        let corpus = Corpus::new(CorpusConfig {
            lines_per_file: 300,
            ..CorpusConfig::default()
        });
        let r = run_hz_wordcount(corpus, JobConfig::default(), 2, 64 * 1024 * 1024).unwrap();
        assert_eq!(r.map_invocations, 3);
        assert!(r.is_conserved());
        assert_eq!(r.nodes, 2);
    }

    #[test]
    fn object_format_configured() {
        let cfg = hz_mr_grid_config(1024, 1);
        assert_eq!(cfg.in_memory_format, InMemoryFormat::Object);
        assert!(cfg.backend.is_hazelcast_like());
    }
}
