"""AOT bridge: lower the L2 graphs to HLO *text* artifacts + a manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla_extension 0.5.1 behind the Rust ``xla`` crate rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (under ``artifacts/``):

* ``burn_b{B}_d{D}_t{T}.hlo.txt``  — workload_step variants
* ``matchmake_c{C}_v{V}.hlo.txt``  — matchmaking variants
* ``manifest.tsv`` — one line per artifact:
  ``kind\tname\tpath\tdims...`` parsed by ``rust/src/runtime/registry.rs``.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import matchmake, workload_step

# Variant tables: small ones for tests/calibration, large for e2e/benches.
BURN_VARIANTS = [
    # (batch, dim, iterations, block_b)
    (64, 128, 16, 64),
    (256, 128, 64, 64),
]
MATCHMAKE_VARIANTS = [
    # (cloudlets, vms, block_c, block_v)
    (256, 64, 64, 64),
    (1024, 256, 64, 64),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_burn(b: int, d: int, t: int, block_b: int) -> str:
    spec = jax.ShapeDtypeStruct((b, d), jnp.float32)
    lowered = workload_step.lower(spec, iterations=t, block_b=block_b)
    return to_hlo_text(lowered)


def lower_matchmake(c: int, v: int, block_c: int, block_v: int) -> str:
    req = jax.ShapeDtypeStruct((c,), jnp.float32)
    cap = jax.ShapeDtypeStruct((v,), jnp.float32)
    load = jax.ShapeDtypeStruct((v,), jnp.float32)
    lowered = matchmake.lower(req, cap, load, block_c=block_c, block_v=block_v)
    return to_hlo_text(lowered)


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []

    for b, d, t, block_b in BURN_VARIANTS:
        name = f"burn_b{b}_d{d}_t{t}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_burn(b, d, t, block_b)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"burn\t{name}\t{os.path.basename(path)}\t{b}\t{d}\t{t}")
        print(f"wrote {path} ({len(text)} chars)")

    for c, v, block_c, block_v in MATCHMAKE_VARIANTS:
        name = f"matchmake_c{c}_v{v}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_matchmake(c, v, block_c, block_v)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"matchmake\t{name}\t{os.path.basename(path)}\t{c}\t{v}\t0")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(manifest_lines)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
