"""L2: the JAX compute graphs the Rust coordinator executes via PJRT.

Two entry points, each calling an L1 Pallas kernel:

* :func:`workload_step` — one burn round for a batch of cloudlet states
  (the Table 5.1 "loaded" workload).
* :func:`matchmake` — fair matchmaking: score matrix (L1) + argmin binding
  decision and per-cloudlet best score (Figs 5.4-5.7 scenario).

These are lowered once by :mod:`compile.aot` to HLO text; Python never runs
on the Rust request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.cloudlet_burn import cloudlet_burn, make_weights
from .kernels.matchmaking import matchmaking_scores


@functools.partial(jax.jit, static_argnames=("iterations", "block_b"))
def workload_step(x: jax.Array, *, iterations: int, block_b: int = 64):
    """Advance a batch of cloudlet workload states by `iterations` burns.

    The weight matrix is a trace-time constant (folded into the artifact),
    so the runtime passes only the state batch.

    Returns a 1-tuple (the AOT bridge lowers with ``return_tuple=True``).
    """
    w = make_weights(x.shape[1])
    return (cloudlet_burn(x, w, iterations=iterations, block_b=block_b),)


@functools.partial(jax.jit, static_argnames=("block_c", "block_v"))
def matchmake(req: jax.Array, cap: jax.Array, load: jax.Array, *, block_c: int = 64, block_v: int = 64):
    """Fair matchmaking decision: ``(assignment int32[c], best_score f32[c])``.

    ``assignment[i]`` is the index of the feasible, fairness-optimal VM for
    cloudlet ``i``; when no VM is feasible the best score is
    ``INFEASIBLE`` and the coordinator falls back to round-robin.
    """
    scores = matchmaking_scores(req, cap, load, block_c=block_c, block_v=block_v)
    assignment = jnp.argmin(scores, axis=1).astype(jnp.int32)
    best = scores.min(axis=1)
    return assignment, best
