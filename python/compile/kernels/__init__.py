"""L1 Pallas kernels for Cloud2Sim's compute hot-spots.

Two kernels, both lowered with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls; see DESIGN.md "Hardware adaptation"):

* :mod:`cloudlet_burn` — the paper's "complex mathematical operation"
  cloudlet workload (Table 5.1 "loaded" runs), a batched iterated
  matmul+tanh chain tiled for VMEM.
* :mod:`matchmaking` — the fair matchmaking-based scheduling score matrix
  (paper 5.1.2), an all-pairs tiled kernel.

``ref`` holds the pure-jnp oracles used by pytest/hypothesis.
"""

from . import cloudlet_burn, matchmaking, ref  # noqa: F401
