"""Fair matchmaking score-matrix Pallas kernel.

The paper's matchmaking scheduling (5.1.2, after Raman et al.) has every
cloudlet "search the object space to find the best fit ... while ensuring
that the minimal specifications are met, cloudlets also ensure fairness, by
not binding to a VM that is much larger than their specification
requirements". That search is the dominant workload — O(C x V) — and is
exactly an all-pairs score computation:

    score[c, v] = waste + ALPHA * load[v] + BETA * relu(waste - FAIR_WINDOW * req[c])
                  where waste = cap[v] - req[c],        if waste >= 0
    score[c, v] = INFEASIBLE                            otherwise

The best (minimum-score) VM per cloudlet is the binding decision.

TPU mapping: classic tiled all-pairs kernel — grid over (cloudlet tiles x
VM tiles); the req tile is a column vector and cap/load tiles are row
vectors broadcast across the (block_c, block_v) VMEM tile. HBM traffic is
O(C + V) per tile row/column instead of O(C*V).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fairness weights: calibrated so load-balance matters but feasibility wins.
ALPHA = 0.25   # per-queued-cloudlet load penalty
BETA = 4.0     # oversize (unfairness) penalty slope
FAIR_WINDOW = 0.5  # waste beyond 50% of the requirement is "unfair"
INFEASIBLE = 1.0e30


def _mm_kernel(req_ref, cap_ref, load_ref, o_ref):
    req = req_ref[...]            # (block_c, 1)
    cap = cap_ref[...]            # (1, block_v)
    load = load_ref[...]          # (1, block_v)
    waste = cap - req             # (block_c, block_v) broadcast
    fair_excess = jnp.maximum(waste - FAIR_WINDOW * req, 0.0)
    score = waste + ALPHA * load + BETA * fair_excess
    o_ref[...] = jnp.where(waste >= 0.0, score, INFEASIBLE)


@functools.partial(jax.jit, static_argnames=("block_c", "block_v"))
def matchmaking_scores(
    req: jax.Array,
    cap: jax.Array,
    load: jax.Array,
    *,
    block_c: int = 64,
    block_v: int = 64,
) -> jax.Array:
    """Score matrix for cloudlet requirements vs VM capacities.

    Args:
      req: ``(c,)`` float32 required VM size per cloudlet.
      cap: ``(v,)`` float32 VM sizes.
      load: ``(v,)`` float32 current VM load (bound-cloudlet count).
      block_c / block_v: tile sizes (c, v must divide evenly).

    Returns:
      ``(c, v)`` float32 scores; ``INFEASIBLE`` marks VMs below spec.
    """
    c, v = req.shape[0], cap.shape[0]
    if c % block_c or v % block_v:
        raise ValueError(f"shapes ({c},{v}) not divisible by blocks ({block_c},{block_v})")
    if load.shape != cap.shape:
        raise ValueError("load and cap must align")
    req2 = req.reshape(c, 1)
    cap2 = cap.reshape(1, v)
    load2 = load.reshape(1, v)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((c, v), jnp.float32),
        grid=(c // block_c, v // block_v),
        in_specs=[
            pl.BlockSpec((block_c, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_v), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_c, block_v), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(req2, cap2, load2)
