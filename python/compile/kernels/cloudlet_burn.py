"""Cloudlet workload ("burn") Pallas kernel.

The paper's loaded simulations attach "a complex mathematical operation" to
every cloudlet (5.1.1). We model one *batch* of cloudlet workloads as a
state matrix ``x``: one row per cloudlet, ``d`` state features. Each burn
iteration applies an affine transform with a fixed weight matrix followed by
``tanh`` — an MXU-friendly matmul chain whose cost scales linearly with the
iteration count, letting the coordinator map cloudlet MI lengths to
iterations.

TPU mapping (DESIGN.md "Hardware-Adaptation"): the batch is tiled into
``(block_b, d)`` VMEM blocks; the ``(d, d)`` weight tile is pinned in VMEM
across the whole grid (its BlockSpec index map is constant), and the
iteration loop is an in-kernel ``fori_loop`` so the chain never round-trips
to HBM. ``d`` defaults to 128 = one MXU lane dimension.

VMEM footprint per program instance (f32): ``block_b*d`` (x) + ``d*d`` (w)
+ ``block_b*d`` (out) floats; for block_b=256, d=128 that is
2*256*128*4 + 128*128*4 = 320 KiB, comfortably inside the ~16 MiB VMEM
budget (DESIGN.md 7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Scale keeps the tanh chain well-conditioned (|x @ W * SCALE| ~ O(1)).
SCALE = 0.1
BIAS = 0.01


def make_weights(d: int, seed: int = 7) -> jax.Array:
    """Deterministic (d, d) weight matrix, constant-folded into the HLO."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (d, d), dtype=jnp.float32) / jnp.sqrt(d)


def _burn_kernel(x_ref, w_ref, o_ref, *, iterations: int):
    """One grid step: iterate the affine+tanh chain on a VMEM-resident tile."""
    w = w_ref[...]

    def body(_, acc):
        return jnp.tanh(jnp.dot(acc, w) * SCALE + BIAS)

    o_ref[...] = jax.lax.fori_loop(0, iterations, body, x_ref[...])


@functools.partial(jax.jit, static_argnames=("iterations", "block_b"))
def cloudlet_burn(x: jax.Array, w: jax.Array, *, iterations: int, block_b: int = 64) -> jax.Array:
    """Run `iterations` burn steps over the cloudlet state batch ``x``.

    Args:
      x: ``(b, d)`` float32 cloudlet state (b divisible by ``block_b``).
      w: ``(d, d)`` float32 weights (see :func:`make_weights`).
      iterations: burn-loop trips; the coordinator maps MI length to this.
      block_b: batch tile size (VMEM sizing knob).

    Returns:
      ``(b, d)`` float32 post-burn state.
    """
    b, d = x.shape
    if b % block_b:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    if w.shape != (d, d):
        raise ValueError(f"weights {w.shape} do not match state dim {d}")
    kernel = functools.partial(_burn_kernel, iterations=iterations)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, d), x.dtype),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),  # W pinned across the grid
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)
