"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
checked by pytest + hypothesis at build time (the paper's accuracy claim:
"the output is consistent as if simulating in a single instance", 3.1.1).
"""

import jax
import jax.numpy as jnp

from .cloudlet_burn import BIAS, SCALE
from .matchmaking import ALPHA, BETA, FAIR_WINDOW, INFEASIBLE


def cloudlet_burn_ref(x: jax.Array, w: jax.Array, *, iterations: int) -> jax.Array:
    """Reference burn chain: plain jnp, no tiling."""

    def body(_, acc):
        return jnp.tanh(acc @ w * SCALE + BIAS)

    return jax.lax.fori_loop(0, iterations, body, x)


def matchmaking_scores_ref(req: jax.Array, cap: jax.Array, load: jax.Array) -> jax.Array:
    """Reference score matrix: broadcast jnp, no tiling."""
    waste = cap[None, :] - req[:, None]
    fair_excess = jnp.maximum(waste - FAIR_WINDOW * req[:, None], 0.0)
    score = waste + ALPHA * load[None, :] + BETA * fair_excess
    return jnp.where(waste >= 0.0, score, INFEASIBLE)


def matchmake_ref(req: jax.Array, cap: jax.Array, load: jax.Array):
    """Reference end-to-end matchmaking: scores -> (assignment, best score)."""
    scores = matchmaking_scores_ref(req, cap, load)
    return jnp.argmin(scores, axis=1).astype(jnp.int32), scores.min(axis=1)
