"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and, for the burn kernel, dtypes) and asserts
allclose against ref — the CORE correctness signal of the build path.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.cloudlet_burn import cloudlet_burn, make_weights
from compile.kernels.matchmaking import INFEASIBLE, matchmaking_scores
from compile.kernels.ref import (
    cloudlet_burn_ref,
    matchmake_ref,
    matchmaking_scores_ref,
)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def rand(key, shape, dtype=jnp.float32, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, dtype=jnp.float32, minval=lo, maxval=hi).astype(dtype)


# ---------------------------------------------------------------- burn ----


@given(
    b_mult=st.integers(min_value=1, max_value=4),
    block_b=st.sampled_from([8, 16, 32]),
    d=st.sampled_from([16, 64, 128]),
    iterations=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_burn_matches_ref_shapes(b_mult, block_b, d, iterations, seed):
    b = b_mult * block_b
    x = rand(seed, (b, d))
    w = make_weights(d)
    got = cloudlet_burn(x, w, iterations=iterations, block_b=block_b)
    want = cloudlet_burn_ref(x, w, iterations=iterations)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_burn_bfloat16(seed):
    x = rand(seed, (32, 64), dtype=jnp.bfloat16)
    w = make_weights(64).astype(jnp.bfloat16)
    got = cloudlet_burn(x, w, iterations=4, block_b=16)
    want = cloudlet_burn_ref(x, w, iterations=4)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=5e-2, atol=5e-2
    )


def test_burn_output_bounded():
    # tanh chain must stay in (-1, 1): numerical stability of long burns
    x = rand(3, (64, 128), lo=-10.0, hi=10.0)
    w = make_weights(128)
    out = cloudlet_burn(x, w, iterations=200, block_b=64)
    assert np.all(np.abs(np.asarray(out)) <= 1.0)
    assert np.all(np.isfinite(np.asarray(out)))


def test_burn_zero_iterations_identity():
    x = rand(5, (16, 16))
    w = make_weights(16)
    out = cloudlet_burn(x, w, iterations=0, block_b=16)
    np.testing.assert_allclose(out, x)


def test_burn_rejects_bad_shapes():
    x = rand(0, (30, 16))
    w = make_weights(16)
    with pytest.raises(ValueError):
        cloudlet_burn(x, w, iterations=1, block_b=16)  # 30 % 16 != 0
    with pytest.raises(ValueError):
        cloudlet_burn(rand(0, (16, 16)), make_weights(8), iterations=1, block_b=16)


def test_burn_iterations_compose():
    # burn(t1+t2) == burn(t2) . burn(t1)
    x = rand(9, (32, 64))
    w = make_weights(64)
    once = cloudlet_burn(x, w, iterations=12, block_b=32)
    twice = cloudlet_burn(
        cloudlet_burn(x, w, iterations=5, block_b=32), w, iterations=7, block_b=32
    )
    np.testing.assert_allclose(once, twice, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- matchmaking ----


@given(
    c_mult=st.integers(min_value=1, max_value=4),
    v_mult=st.integers(min_value=1, max_value=4),
    block=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matchmaking_matches_ref(c_mult, v_mult, block, seed):
    c, v = c_mult * block, v_mult * block
    req = rand(seed, (c,), lo=1.0, hi=10.0)
    cap = rand(seed + 1, (v,), lo=1.0, hi=20.0)
    load = rand(seed + 2, (v,), lo=0.0, hi=8.0)
    got = matchmaking_scores(req, cap, load, block_c=block, block_v=block)
    want = matchmaking_scores_ref(req, cap, load)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_matchmaking_infeasible_marked():
    req = jnp.full((8,), 100.0)
    cap = jnp.full((8,), 1.0)  # nothing fits
    load = jnp.zeros((8,))
    scores = matchmaking_scores(req, cap, load, block_c=8, block_v=8)
    assert np.all(np.asarray(scores) == INFEASIBLE)


def test_matchmaking_prefers_snug_fit():
    # req=10; caps 11 (snug), 100 (wasteful), 5 (infeasible) → pick 11
    req = jnp.full((8,), 10.0)
    cap = jnp.array([11.0, 100.0, 5.0] + [5.0] * 5)
    load = jnp.zeros((8,))
    assign, best = matchmake_ref(req, cap, load)
    assert np.all(np.asarray(assign) == 0)
    assert np.all(np.asarray(best) < INFEASIBLE)


def test_matchmaking_fairness_avoids_loaded_vm():
    # two equal snug VMs, one heavily loaded → pick the idle one
    req = jnp.full((8,), 10.0)
    cap = jnp.array([11.0, 11.0] + [1.0] * 6)
    load = jnp.array([50.0, 0.0] + [0.0] * 6)
    assign, _ = matchmake_ref(req, cap, load)
    assert np.all(np.asarray(assign) == 1)
