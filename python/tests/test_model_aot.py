"""L2 + AOT-bridge tests: the jitted model graphs compose the kernels
correctly, and the lowering path emits loadable HLO text + manifest."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels.ref import cloudlet_burn_ref, matchmake_ref
from compile.kernels.cloudlet_burn import make_weights
from compile.model import matchmake, workload_step


def test_workload_step_matches_ref():
    x = jax.random.uniform(jax.random.PRNGKey(0), (64, 128), minval=-1, maxval=1)
    (got,) = workload_step(x, iterations=16, block_b=64)
    want = cloudlet_burn_ref(x, make_weights(128), iterations=16)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matchmake_matches_ref():
    k = jax.random.PRNGKey(1)
    req = jax.random.uniform(k, (128,), minval=1.0, maxval=10.0)
    cap = jax.random.uniform(jax.random.PRNGKey(2), (64,), minval=1.0, maxval=20.0)
    load = jax.random.uniform(jax.random.PRNGKey(3), (64,), minval=0.0, maxval=5.0)
    assign, best = matchmake(req, cap, load, block_c=64, block_v=64)
    ref_assign, ref_best = matchmake_ref(req, cap, load)
    np.testing.assert_array_equal(assign, ref_assign)
    np.testing.assert_allclose(best, ref_best, rtol=1e-6)


def test_lowering_emits_hlo_text():
    text = aot.lower_burn(64, 128, 4, 64)
    # HLO text (not proto): the id-safe interchange format
    assert "ENTRY" in text
    assert "f32[64,128]" in text
    # the fori_loop must lower to a while, not a 4x unroll
    assert text.count("while") >= 1
    assert text.count(" dot(") <= 2, "burn chain must not unroll its matmuls"


def test_lowering_matchmake_shapes():
    text = aot.lower_matchmake(256, 64, 64, 64)
    assert "f32[256]" in text and "f32[64]" in text
    assert "s32[256]" in text, "assignment output is int32"


def test_manifest_written(tmp_path):
    # run the real CLI path into a temp dir
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr
    manifest = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert len(manifest) == len(aot.BURN_VARIANTS) + len(aot.MATCHMAKE_VARIANTS)
    for line in manifest:
        kind, name, fname, d1, d2, d3 = line.split("\t")
        assert kind in ("burn", "matchmake")
        assert (tmp_path / fname).exists(), f"artifact {fname} missing"
        assert int(d1) > 0 and int(d2) > 0 and int(d3) >= 0
        assert name in fname


def test_artifact_is_deterministic():
    a = aot.lower_burn(64, 128, 16, 64)
    b = aot.lower_burn(64, 128, 16, 64)
    assert a == b, "same variant must lower to identical HLO (reproducible builds)"


def test_weights_are_not_runtime_inputs():
    # the weight matrix is produced inside the artifact (traced PRNG →
    # constants at run time), NOT passed by the Rust caller: the ENTRY
    # computation takes exactly one parameter — the state batch
    text = aot.lower_burn(64, 128, 4, 64)
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    entry = []
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        entry.append(l)
    params = [l for l in entry if " parameter(" in l]
    assert len(params) == 1, f"ENTRY must take only the state batch: {params}"
    assert "f32[64,128]" in params[0]
