#!/usr/bin/env python3
"""Gate the fault-injection recovery evidence.

The fault scenarios referee themselves in-run: any result divergence
between a faulted run and its fault-free twin hard-errors before a report
even exists. This gate re-asserts the *evidence of injection* from the
JSON — crashes happened, work was re-executed, the backup copy won at
least once — so a silently defanged fault plan fails CI even when parity
trivially holds. It also assembles the reviewable fault-event log
artifact (``BENCH_fault_events.json``).

The pure core :func:`check_faults` takes the two parsed reports and
returns ``(lines, failures, events_doc)`` so ``ci/test_gates.py`` can
unit-test the logic without touching disk.
"""

import argparse
import json
import sys


def _scenario(report, name):
    for s in report.get("scenarios", []):
        if s.get("name") == name:
            return s
    return None


def check_faults(churn_report, straggler_report, failover_report=None):
    """Pure gate core: parsed reports -> (lines, failures, events_doc).

    ``failover_report`` is optional so the original two-report invocation
    keeps working; when given it must contain ``megascale_dc_failover``
    with live datacenter-crash evidence.
    """
    lines, failures = [], []
    events_doc = {}

    churn = _scenario(churn_report, "member_churn_elastic")
    if churn is None:
        failures.append("member_churn_elastic missing from its report")
    else:
        e = churn.get("extras", {})
        for key in ("crashes", "rejoins", "tasks_reexecuted", "entries_migrated"):
            if key in e:
                lines.append(f"{key:<19}: {e[key]:.0f}")
        if "churn_virtual_overhead_s" in e:
            lines.append(f"churn overhead (vs): {e['churn_virtual_overhead_s']:.3f} s")
        if not e.get("tasks_reexecuted", 0) > 0:
            failures.append("churn must re-execute lost work")
        if not (e.get("crashes", 0) >= 1 and e.get("rejoins", 0) >= 1):
            failures.append("churn plan must crash and rejoin at least once")
        if e.get("entries_lost", 1) != 0:
            failures.append("backups must migrate entries, not lose them")
        if not e.get("cloudlets_ok", 0) > 0:
            failures.append("referee parity evidence missing (cloudlets_ok)")
        actions = [ev.get("action") for ev in churn.get("scale_events", [])]
        if "crash" not in actions or "rejoin" not in actions:
            failures.append(f"crash/rejoin missing from the scale-event log: {actions}")
        events_doc["member_churn_elastic"] = {
            "scale_events": churn.get("scale_events", []),
            "extras": dict(e),
        }

    spec = _scenario(straggler_report, "mr_straggler_speculative")
    if spec is None:
        failures.append("mr_straggler_speculative missing from its report")
    else:
        se = spec.get("extras", {})
        if "speculative_wins" in se:
            lines.append(f"speculative_wins   : {se['speculative_wins']:.0f}")
        if not se.get("speculative_wins", 0) > 0:
            failures.append("the backup copy must beat the straggler at least once")
        if not se.get("fault_events", 0) > 0:
            failures.append("no fault events were injected")
        events_doc["mr_straggler_speculative"] = {"extras": dict(se)}

    if failover_report is not None:
        failover = _scenario(failover_report, "megascale_dc_failover")
        if failover is None:
            failures.append("megascale_dc_failover missing from its report")
        else:
            fe = failover.get("extras", {})
            for key in ("dc_crashes", "dc_recovers", "rebound",
                        "retries_exhausted", "cloudlets_failed"):
                if key in fe:
                    lines.append(f"{key:<19}: {fe[key]:.0f}")
            if not fe.get("dc_crashes", 0) >= 1:
                failures.append("the datacenter fault plan never crashed a dc")
            if not fe.get("rebound", 0) > 0:
                failures.append("the dc crash must interrupt and re-bind work")
            if not fe.get("fault_fingerprint", 0) > 0:
                failures.append("fault-log fingerprint evidence missing")
            ok = fe.get("cloudlets_ok", 0)
            failed = fe.get("cloudlets_failed", 0)
            if not ok > 0:
                failures.append("referee parity evidence missing (cloudlets_ok)")
            if not failed <= ok:
                failures.append(
                    f"failures unbounded: {failed:.0f} failed vs {ok:.0f} ok"
                )
            tenants = int(fe.get("tenants", 0))
            for t in range(tenants):
                if not fe.get(f"tenant_{t}_completed", 0) > 0:
                    failures.append(f"tenant {t} starved under the dc crash")
            actions = [ev.get("action") for ev in failover.get("scale_events", [])]
            if "dc-crash" not in actions or "dc-recover" not in actions:
                failures.append(
                    f"dc-crash/dc-recover missing from the scale-event log: {actions}"
                )
            events_doc["megascale_dc_failover"] = {
                "scale_events": failover.get("scale_events", []),
                "extras": dict(fe),
            }

    return lines, failures, events_doc


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "churn",
        nargs="?",
        default="BENCH_fault_churn.json",
        help="member_churn_elastic report (default: %(default)s)",
    )
    p.add_argument(
        "straggler",
        nargs="?",
        default="BENCH_fault_straggler.json",
        help="mr_straggler_speculative report (default: %(default)s)",
    )
    p.add_argument(
        "failover",
        nargs="?",
        default=None,
        help="optional megascale_dc_failover report (e.g. BENCH_dc_failover.json)",
    )
    p.add_argument(
        "--events-out",
        default="BENCH_fault_events.json",
        help="where to write the fault-event log artifact (default: %(default)s)",
    )
    args = p.parse_args(argv)
    with open(args.churn) as f:
        churn_report = json.load(f)
    with open(args.straggler) as f:
        straggler_report = json.load(f)
    failover_report = None
    if args.failover is not None:
        with open(args.failover) as f:
            failover_report = json.load(f)
    lines, failures, events_doc = check_faults(
        churn_report, straggler_report, failover_report
    )
    for line in lines:
        print(line)
    with open(args.events_out, "w") as f:
        json.dump(events_doc, f, indent=2, sort_keys=True)
    for failure in failures:
        print(f"FAIL {failure}", file=sys.stderr)
    if failures:
        return 1
    print("fault gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
