#!/usr/bin/env python3
"""Gate the megascale word count's parallel-pipeline wall-clock win.

Reads a ``cloud2sim-bench/2`` report (``BENCH_megascale_wordcount.json``)
and re-asserts the scenario's floors: at least 2M distinct keys reduced,
a positive pairs/sec figure, and the parallel shuffle/reduce pipeline
beating the sequential tail on wall clock. Both walls are per-pipeline
minima across the bench repetitions (best observed vs best observed), so
a cold-start stall on repetition one cannot flip the verdict.

The pure core :func:`check_wordcount` takes the parsed report and returns
``(lines, failures)`` — printable evidence and failure strings — so
``ci/test_gates.py`` can unit-test the gate logic without touching disk.
"""

import argparse
import json
import sys


def check_wordcount(report):
    """Pure gate core: parsed report -> (printable lines, failures)."""
    lines, failures = [], []
    matches = [
        s for s in report.get("scenarios", []) if s.get("name") == "megascale_wordcount"
    ]
    if not matches:
        return lines, ["megascale_wordcount missing from the report"]
    sc = matches[0]
    extras = sc.get("extras", {})
    walls = sc.get("wall_extras", {})
    pairs = sc.get("pairs_per_sec")
    reduces = extras.get("reduce_invocations")
    par = walls.get("wall_parallel_s")
    seq = walls.get("wall_sequential_s")

    if pairs is not None:
        lines.append(f"pairs_per_sec      : {pairs:.0f}")
    if reduces is not None:
        lines.append(f"reduce_invocations : {reduces:.0f}")
    if par is not None and seq is not None:
        lines.append(f"wall parallel      : {par * 1e3:.0f} ms")
        lines.append(f"wall sequential    : {seq * 1e3:.0f} ms")
        if par > 0:
            lines.append(f"wall speedup       : {seq / par:.2f}x")

    if reduces is None or reduces < 2e6:
        failures.append("megascale floor broken: need >= 2M distinct keys reduced")
    if not pairs or pairs <= 0:
        failures.append("pairs_per_sec missing or non-positive")
    if par is None or seq is None:
        failures.append("per-pipeline walls missing from wall_extras")
    elif not par < seq:
        failures.append("parallel shuffle/reduce must beat the sequential tail on wall clock")
    return lines, failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "report",
        nargs="?",
        default="BENCH_megascale_wordcount.json",
        help="bench report to gate (default: %(default)s)",
    )
    args = p.parse_args(argv)
    with open(args.report) as f:
        report = json.load(f)
    lines, failures = check_wordcount(report)
    for line in lines:
        print(line)
    for failure in failures:
        print(f"FAIL {failure}", file=sys.stderr)
    if failures:
        return 1
    print("wordcount gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
