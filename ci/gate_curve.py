#!/usr/bin/env python3
"""Shape-gate scaling curves (``cloud2sim-curve/1``) against a baseline.

This is the CI side of the sweep harness's gating philosophy, mirroring
``rust/src/bench/curve.rs`` exactly:

* every *virtual* quantity — axis values, per-cell virtual times and
  deterministic extras, every non-wall series — must match the baseline
  **bit for bit** (sweeps are as deterministic as single scenarios);
* *wall* series are never compared point for point. Each sweep carries
  its declared shape gates as data (the same ``gates`` array the Rust
  ``--compare`` path interprets), and this script evaluates them:
  monotone trajectories within a relative tolerance, strict curve
  ordering (Infinispan below Hazelcast), and knee location within a cell
  tolerance of the baseline's knee — with a noise floor that skips wall
  gates when the sweep ran too fast to carry signal, and a core cap so a
  2-core runner is never asked to show 8-way wall speedup.

``--require`` names sweeps that must be present AND still declare a
monotone speedup gate plus a knee gate, so a regression cannot pass by
silently dropping a sweep or defanging its gate declarations.

The pure cores (:func:`knee_index`, :func:`check_gate`,
:func:`compare_curves`, :func:`check_required`) are unit-tested by
``ci/test_gates.py``.
"""

import argparse
import json
import math
import os
import struct
import sys

SCHEMA = "cloud2sim-curve/1"


def bits(v):
    """Bit pattern of a float — the equality virtual quantities are held
    to (so -0.0 vs 0.0 counts as drift, exactly like ``f64::to_bits``)."""
    return struct.pack("<d", float(v))


def series_values(sweep, name):
    """Values of a named series, or None when the sweep lacks it."""
    for s in sweep.get("series", []):
        if s.get("name") == name:
            return s.get("values", [])
    return None


def knee_index(values, frac):
    """Smallest index reaching ``frac`` of the series maximum (finite
    values only); None when nothing is finite."""
    finite = [v for v in values if math.isfinite(v)]
    if not finite:
        return None
    peak = max(finite)
    for i, v in enumerate(values):
        if math.isfinite(v) and v >= frac * peak:
            return i
    return None


def _gate_range(gate, sweep, cores):
    cells = sweep.get("cells", [])
    return [
        i
        for i in range(int(gate.get("from", 0)), len(cells))
        if not gate.get("cap_to_cores") or cells[i].get("x", 0.0) <= cores
    ]


def check_gate(gate, sweep, baseline_sweep, cores):
    """Evaluate one gate. Returns a failure string, or None when the gate
    passes or is skipped (noise floor, knee without a baseline)."""
    name = sweep.get("name", "?")
    series = gate.get("series", "?")

    def fail(msg):
        return f"{name}: {series} {msg}"

    values = series_values(sweep, series)
    if values is None:
        return fail(f"series missing (gate {gate.get('kind')})")
    if gate.get("wall"):
        # noise floor: when even the largest cell wall is below the
        # floor, the whole sweep ran too fast to carry wall signal
        max_wall = max(
            (c.get("wall_min_s", 0.0) for c in sweep.get("cells", [])), default=0.0
        )
        if max_wall < gate.get("min_ref_wall_s", 0.0):
            return None
    rng = _gate_range(gate, sweep, cores)
    kind = gate.get("kind")

    if kind in ("monotone_nondecreasing", "monotone_nonincreasing"):
        decreasing = kind == "monotone_nonincreasing"
        rel_tol = gate.get("rel_tol", 0.0)
        extremum = None
        for i in rng:
            v = values[i]
            if not math.isfinite(v):
                return fail(f"non-finite value at cell {i}")
            if extremum is not None:
                bound = extremum * (1.0 + rel_tol) if decreasing else extremum * (1.0 - rel_tol)
                broken = v > bound if decreasing else v < bound
                if broken:
                    x = sweep["cells"][i].get("x")
                    word = "nonincreasing" if decreasing else "nondecreasing"
                    return fail(
                        f"not monotone {word} at x={x}: {v} vs bound {bound} (tol {rel_tol})"
                    )
                extremum = min(extremum, v) if decreasing else max(extremum, v)
            else:
                extremum = v
        return None

    if kind == "ordering_below":
        other = gate.get("other")
        if not other:
            return fail("ordering gate without an upper series")
        upper = series_values(sweep, other)
        if upper is None:
            return fail(f"upper series {other} missing")
        for i in rng:
            if not values[i] < upper[i]:
                x = sweep["cells"][i].get("x")
                return fail(f"ordering broken at x={x}: {values[i]} !< {upper[i]} ({other})")
        return None

    if kind == "knee":
        base_values = series_values(baseline_sweep, series) if baseline_sweep else None
        if base_values is None:
            # bootstrap: no baseline yet, nothing to anchor the knee to
            return None

        def pick(sw, vals):
            # cap both sides with the *current* machine's cores so the
            # comparison is self-consistent on whatever runner executes it
            cells = sw.get("cells", [])
            return [
                vals[i]
                for i in range(len(vals))
                if not gate.get("cap_to_cores")
                or (i < len(cells) and cells[i].get("x", 0.0) <= cores)
            ]

        frac = gate.get("frac", 0.0)
        cur = knee_index(pick(sweep, values), frac)
        base = knee_index(pick(baseline_sweep, base_values), frac)
        if cur is None or base is None:
            return fail("knee undefined (non-finite series)")
        tol = int(gate.get("knee_tol", 0))
        if abs(cur - base) > tol:
            return fail(f"knee moved from cell {base} to {cur} (tol {tol})")
        return None

    return fail(f"unknown gate kind {kind}")


def check_sweep_gates(sweep, baseline_sweep, cores, include_wall):
    """Evaluate every declared gate of one sweep."""
    fails = []
    for gate in sweep.get("gates", []):
        if not include_wall and gate.get("wall"):
            continue
        msg = check_gate(gate, sweep, baseline_sweep, cores)
        if msg is not None:
            fails.append(msg)
    return fails


def compare_curves(current, baseline, cores):
    """Full curve compare: bit-exact on virtual quantities, declared shape
    gates on everything else. Returns a dict with ``drifts``, ``missing``,
    ``unchecked`` and ``shape_failures`` lists."""
    out = {"drifts": [], "missing": [], "unchecked": [], "shape_failures": []}
    cur_by_name = {s.get("name"): s for s in current.get("sweeps", [])}
    base_names = set()
    for b in baseline.get("sweeps", []):
        name = b.get("name")
        base_names.add(name)
        c = cur_by_name.get(name)
        if c is None:
            out["missing"].append(name)
            continue

        def check(field, cur_v, base_v):
            if bits(cur_v) != bits(base_v):
                out["drifts"].append(f"{name}: {field} changed {base_v} -> {cur_v}")

        if c.get("axis") != b.get("axis"):
            out["drifts"].append(
                f"{name}: axis changed {b.get('axis')} -> {c.get('axis')}"
            )
            continue
        b_cells, c_cells = b.get("cells", []), c.get("cells", [])
        check("cells.len", len(c_cells), len(b_cells))
        for i, (cc, bc) in enumerate(zip(c_cells, b_cells)):
            check(f"cells[{i}].x", cc.get("x", float("nan")), bc.get("x", float("nan")))
            check(
                f"cells[{i}].virtual_s",
                cc.get("virtual_s", float("nan")),
                bc.get("virtual_s", float("nan")),
            )
            for k, bv in bc.get("extras", {}).items():
                cv = cc.get("extras", {}).get(k, float("nan"))
                check(f"cells[{i}].extras.{k}", cv, bv)
        for bs in b.get("series", []):
            if bs.get("wall"):
                continue  # wall series are shape-gated, never bit-compared
            cv = series_values(c, bs.get("name"))
            if cv is None:
                out["drifts"].append(f"{name}: series {bs.get('name')} disappeared")
                continue
            b_vals = bs.get("values", [])
            check(f"series.{bs.get('name')}.len", len(cv), len(b_vals))
            for i, (x, y) in enumerate(zip(cv, b_vals)):
                check(f"series.{bs.get('name')}[{i}]", x, y)
        # shape gates: the current run's declarations, anchored to the
        # baseline where a gate needs one (knee location)
        out["shape_failures"].extend(check_sweep_gates(c, b, cores, True))
    for name, c in cur_by_name.items():
        if name not in base_names:
            out["unchecked"].append(name)
            # a new sweep still gets its own shape gates (no knee anchor)
            out["shape_failures"].extend(check_sweep_gates(c, None, cores, True))
    return out


def check_required(current, required_names):
    """Anti-defanging: each required sweep must exist and still declare a
    monotone speedup gate plus a knee gate."""
    fails = []
    by_name = {s.get("name"): s for s in current.get("sweeps", [])}
    for name in required_names:
        sweep = by_name.get(name)
        if sweep is None:
            fails.append(f"required sweep {name} is missing from the report")
            continue
        gates = sweep.get("gates", [])
        has_speedup_monotone = any(
            g.get("kind") == "monotone_nondecreasing" and "speedup" in g.get("series", "")
            for g in gates
        )
        has_knee = any(g.get("kind") == "knee" for g in gates)
        if not has_speedup_monotone:
            fails.append(f"required sweep {name} no longer declares a monotone speedup gate")
        if not has_knee:
            fails.append(f"required sweep {name} no longer declares a knee gate")
    return fails


def _load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"FAIL {path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    return doc


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("current", help="curve report of this run (BENCH_curves.json)")
    p.add_argument("baseline", help="committed baseline (ci/BENCH_curves_baseline.json)")
    p.add_argument(
        "--require",
        default="",
        help="comma-separated sweep names that must be present and keep "
        "their monotone-speedup + knee gate declarations",
    )
    p.add_argument(
        "--cores",
        type=int,
        default=0,
        help="core count for cap_to_cores gates (default: detected)",
    )
    args = p.parse_args(argv)
    current = _load(args.current)
    baseline = _load(args.baseline)
    cores = args.cores if args.cores > 0 else (os.cpu_count() or 1)

    failures = check_required(current, [n for n in args.require.split(",") if n])
    cmp = compare_curves(current, baseline, cores)
    for d in cmp["drifts"]:
        print(f"DRIFT {d}")
    for m in cmp["missing"]:
        print(f"MISSING {m}: in baseline but not in this run")
    for u in cmp["unchecked"]:
        print(f"NEW {u}: no baseline entry yet (not gated)")
    for s in cmp["shape_failures"]:
        print(f"SHAPE {s}")
    if not baseline.get("sweeps"):
        print("note: baseline is the empty bootstrap - the next push to main arms it")
    failures += cmp["drifts"] + cmp["missing"] + cmp["shape_failures"]
    for failure in failures:
        print(f"FAIL {failure}", file=sys.stderr)
    if failures:
        return 1
    print("curve gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
