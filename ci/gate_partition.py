#!/usr/bin/env python3
"""Gate the transport-fault / split-brain recovery evidence.

``mr_partition_splitbrain`` referees itself in-run: the worker-count
rerun must reproduce the fault-log fingerprint and clock bits, and the
fault-free twin must match every result statistic bit-for-bit — any
drift hard-errors before a report exists. This gate re-asserts the
*evidence of injection* from the JSON — deliveries were dropped and
retried, the receiver deduplicated at least one duplicate, the partition
cut and healed, the split-brain merge was recorded — so a silently
defanged link-fault plan fails CI even when parity trivially holds.
Given a second report from an independent run it also cross-checks the
fingerprint and every deterministic quantity byte-for-byte.

The pure core :func:`check_partition` takes the parsed report(s) and
returns ``(lines, failures, events_doc)`` so ``ci/test_gates.py`` can
unit-test the logic without touching disk.
"""

import argparse
import json
import sys


def _scenario(report, name):
    for s in report.get("scenarios", []):
        if s.get("name") == name:
            return s
    return None


def check_partition(report, rerun_report=None):
    """Pure gate core: parsed report(s) -> (lines, failures, events_doc).

    ``rerun_report`` is optional; when given it must contain the same
    scenario and agree on the fingerprint, the virtual time and every
    extra exactly (the run-twice determinism contract, re-checked here
    on the transport surface specifically).
    """
    lines, failures = [], []
    events_doc = {}

    s = _scenario(report, "mr_partition_splitbrain")
    if s is None:
        failures.append("mr_partition_splitbrain missing from its report")
        return lines, failures, events_doc

    e = s.get("extras", {})
    for key in (
        "net_messages",
        "net_retries",
        "net_dropped",
        "net_deduplicated",
        "split_brain_merges",
        "fault_events",
    ):
        if key in e:
            lines.append(f"{key:<19}: {e[key]:.0f}")
    if "partition_virtual_overhead_s" in e:
        lines.append(
            f"partition overhead : {e['partition_virtual_overhead_s']:.3f} s (virtual)"
        )

    if not e.get("net_retries", 0) > 0:
        failures.append("lossy links must force at least one ack-timeout retry")
    if not e.get("net_deduplicated", 0) >= 1:
        failures.append("receiver-side dedup must catch at least one duplicate")
    if not e.get("net_dropped", 0) > 0:
        failures.append("the link-fault plan never dropped a delivery attempt")
    if not e.get("split_brain_merges", 0) >= 1:
        failures.append("no split-brain merge was recorded")
    if not e.get("fault_fingerprint", 0) > 0:
        failures.append("fault-log fingerprint evidence missing")
    if not e.get("emitted_pairs", 0) > 0:
        failures.append("referee parity evidence missing (emitted_pairs)")
    if not e.get("sim_time_nofault_s", 0) > 0:
        failures.append("the fault-free twin's virtual time is missing")
    if e.get("partition_virtual_overhead_s", -1) < 0:
        failures.append("the partition may not make the job faster than clean")

    actions = [ev.get("action") for ev in s.get("scale_events", [])]
    for needed in ("link-partition", "split-brain", "link-heal", "split-brain-merge"):
        if needed not in actions:
            failures.append(f"{needed} missing from the scale-event log: {actions}")

    if rerun_report is not None:
        r = _scenario(rerun_report, "mr_partition_splitbrain")
        if r is None:
            failures.append("mr_partition_splitbrain missing from the rerun report")
        else:
            if s.get("virtual_s") != r.get("virtual_s"):
                failures.append(
                    "virtual time drifted between runs: "
                    f"{s.get('virtual_s')} vs {r.get('virtual_s')}"
                )
            re_extras = r.get("extras", {})
            for key, val in e.items():
                if re_extras.get(key) != val:
                    failures.append(
                        f"extra {key} drifted between runs: "
                        f"{val} vs {re_extras.get(key)}"
                    )
            if s.get("scale_events") != r.get("scale_events"):
                failures.append("the partition scale-event log drifted between runs")

    events_doc["mr_partition_splitbrain"] = {
        "scale_events": s.get("scale_events", []),
        "extras": dict(e),
    }
    return lines, failures, events_doc


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "report",
        nargs="?",
        default="BENCH_partition.json",
        help="mr_partition_splitbrain report (default: %(default)s)",
    )
    p.add_argument(
        "rerun",
        nargs="?",
        default=None,
        help="optional second run of the same scenario for the byte-equality check",
    )
    p.add_argument(
        "--events-out",
        default="BENCH_partition_events.json",
        help="where to write the transport fault-event artifact (default: %(default)s)",
    )
    args = p.parse_args(argv)
    with open(args.report) as f:
        report = json.load(f)
    rerun_report = None
    if args.rerun is not None:
        with open(args.rerun) as f:
            rerun_report = json.load(f)
    lines, failures, events_doc = check_partition(report, rerun_report)
    for line in lines:
        print(line)
    with open(args.events_out, "w") as f:
        json.dump(events_doc, f, indent=2, sort_keys=True)
    for failure in failures:
        print(f"FAIL {failure}", file=sys.stderr)
    if failures:
        return 1
    print("partition gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
