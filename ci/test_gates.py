#!/usr/bin/env python3
"""Unit tests for the pure cores of the CI gate scripts.

Run with ``python3 ci/test_gates.py``. These mirror the Rust unit tests
in ``rust/src/bench/curve.rs`` so the two interpreters of the serialized
gate declarations cannot silently diverge.
"""

import math
import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import gate_curve
import gate_faults
import gate_multitenant
import gate_partition
import gate_wordcount


def sweep(name="workers", xs=(1.0, 2.0, 4.0), walls=(1.0, 0.55, 0.3)):
    """A worker-scaling-shaped sweep with wall shape gates."""
    return {
        "name": name,
        "scenario": "megascale_wordcount",
        "kind": "worker-scaling",
        "axis": "workers",
        "cells": [
            {"x": x, "virtual_s": 5.0, "extras": {"reduce_invocations": 100.0},
             "wall_min_s": w, "wall_extras": {}}
            for x, w in zip(xs, walls)
        ],
        "series": [
            {"name": "virtual_s", "wall": False, "values": [5.0] * len(xs)},
            {"name": "wall_speedup", "wall": True, "values": [1.0, 1.8, 3.3][: len(xs)]},
        ],
        "gates": [
            {"kind": "monotone_nondecreasing", "series": "wall_speedup", "other": None,
             "from": 0, "rel_tol": 0.35, "frac": 0.0, "knee_tol": 0, "wall": True,
             "cap_to_cores": True, "min_ref_wall_s": 0.05},
            {"kind": "knee", "series": "wall_speedup", "other": None, "from": 0,
             "rel_tol": 0.0, "frac": 0.9, "knee_tol": 1, "wall": True,
             "cap_to_cores": True, "min_ref_wall_s": 0.05},
        ],
    }


def report(sweeps):
    return {"schema": "cloud2sim-curve/1", "quick": True, "reps": 1, "sweeps": sweeps}


def set_series(sw, name, values):
    for s in sw["series"]:
        if s["name"] == name:
            s["values"] = list(values)


class TestKneeIndex(unittest.TestCase):
    def test_basic(self):
        self.assertEqual(gate_curve.knee_index([1.0, 1.8, 3.3], 0.9), 2)
        self.assertEqual(gate_curve.knee_index([1.0, 3.2, 3.3], 0.9), 1)
        self.assertEqual(gate_curve.knee_index([3.3, 1.8, 1.0], 0.9), 0)

    def test_non_finite(self):
        self.assertIsNone(gate_curve.knee_index([float("nan"), float("inf")], 0.9))
        self.assertEqual(gate_curve.knee_index([float("nan"), 2.0], 0.9), 1)


class TestCheckGate(unittest.TestCase):
    def test_monotone_within_tolerance_passes(self):
        sw = sweep()
        self.assertIsNone(gate_curve.check_gate(sw["gates"][0], sw, None, 8))
        # a dip inside rel_tol passes: 1.8 * (1 - 0.35) = 1.17 bound
        set_series(sw, "wall_speedup", [1.0, 1.8, 1.2])
        self.assertIsNone(gate_curve.check_gate(sw["gates"][0], sw, None, 8))

    def test_monotone_collapse_fails(self):
        sw = sweep()
        set_series(sw, "wall_speedup", [1.0, 1.8, 0.9])
        msg = gate_curve.check_gate(sw["gates"][0], sw, None, 8)
        self.assertIn("not monotone", msg)

    def test_monotone_nonincreasing(self):
        sw = sweep()
        gate = dict(sw["gates"][0], kind="monotone_nonincreasing", wall=False)
        set_series(sw, "wall_speedup", [3.0, 2.0, 1.0])
        self.assertIsNone(gate_curve.check_gate(gate, sw, None, 8))
        set_series(sw, "wall_speedup", [3.0, 1.0, 2.0])
        self.assertIn("not monotone", gate_curve.check_gate(gate, sw, None, 8))

    def test_noise_floor_skips_wall_gates(self):
        sw = sweep(walls=(0.01, 0.006, 0.011))
        set_series(sw, "wall_speedup", [1.0, 1.8, 0.9])  # collapsed...
        self.assertIsNone(gate_curve.check_gate(sw["gates"][0], sw, None, 8))

    def test_cap_to_cores_drops_oversized_cells(self):
        sw = sweep()
        set_series(sw, "wall_speedup", [1.0, 1.8, 0.9])  # fails at x=4
        self.assertIsNone(gate_curve.check_gate(sw["gates"][0], sw, None, 2))

    def test_from_skips_leading_cells(self):
        # the hz 1->2 collapse pattern: from=1 skips the first transition
        sw = sweep()
        gate = dict(sw["gates"][0], wall=False, cap_to_cores=False)
        gate["from"] = 1
        set_series(sw, "wall_speedup", [9.0, 1.0, 1.5])
        self.assertIsNone(gate_curve.check_gate(gate, sw, None, 8))
        gate["from"] = 0
        self.assertIn("not monotone", gate_curve.check_gate(gate, sw, None, 8))

    def test_ordering_below(self):
        sw = sweep()
        sw["series"].append({"name": "inf", "wall": False, "values": [1.0, 2.0, 3.0]})
        sw["series"].append({"name": "hz", "wall": False, "values": [2.0, 3.0, 4.0]})
        gate = {"kind": "ordering_below", "series": "inf", "other": "hz", "from": 0,
                "rel_tol": 0.0, "frac": 0.0, "knee_tol": 0, "wall": False,
                "cap_to_cores": False, "min_ref_wall_s": 0.0}
        self.assertIsNone(gate_curve.check_gate(gate, sw, None, 8))
        set_series(sw, "inf", [1.0, 3.0, 3.0])  # tie at x=2 is a violation
        self.assertIn("ordering broken", gate_curve.check_gate(gate, sw, None, 8))

    def test_knee_needs_baseline_and_tolerates_one_cell(self):
        sw = sweep()
        gate = sw["gates"][1]
        self.assertIsNone(gate_curve.check_gate(gate, sw, None, 8), "bootstrap skips")
        base = sweep()
        self.assertIsNone(gate_curve.check_gate(gate, sw, base, 8))
        # knee at cell 1 vs baseline cell 2: within tol 1
        set_series(sw, "wall_speedup", [1.0, 3.2, 3.3])
        self.assertIsNone(gate_curve.check_gate(gate, sw, base, 8))
        # knee at cell 0 vs baseline cell 2: moved 2 > tol 1
        set_series(sw, "wall_speedup", [3.3, 1.8, 1.0])
        self.assertIn("knee moved", gate_curve.check_gate(gate, sw, base, 8))

    def test_missing_series_fails(self):
        sw = sweep()
        gate = dict(sw["gates"][0], series="no_such_series")
        self.assertIn("series missing", gate_curve.check_gate(gate, sw, None, 8))


class TestCompareCurves(unittest.TestCase):
    def test_identical_reports_pass(self):
        r = report([sweep()])
        cmp = gate_curve.compare_curves(r, report([sweep()]), 8)
        self.assertEqual(cmp["drifts"], [])
        self.assertEqual(cmp["shape_failures"], [])

    def test_one_ulp_virtual_drift_detected(self):
        cur = report([sweep()])
        cur["sweeps"][0]["cells"][1]["virtual_s"] = math.nextafter(5.0, 6.0)
        cmp = gate_curve.compare_curves(cur, report([sweep()]), 8)
        self.assertTrue(any("virtual_s" in d for d in cmp["drifts"]), cmp)

    def test_negative_zero_is_drift(self):
        cur = report([sweep()])
        cur["sweeps"][0]["cells"][0]["extras"]["reduce_invocations"] = -0.0
        base = report([sweep()])
        base["sweeps"][0]["cells"][0]["extras"]["reduce_invocations"] = 0.0
        cmp = gate_curve.compare_curves(cur, base, 8)
        self.assertTrue(any("extras" in d for d in cmp["drifts"]), cmp)

    def test_wall_values_never_bit_compared(self):
        cur = report([sweep(walls=(30.0, 20.0, 10.0))])
        set_series(cur["sweeps"][0], "wall_speedup", [1.0, 1.5, 3.0])
        cmp = gate_curve.compare_curves(cur, report([sweep()]), 8)
        self.assertEqual(cmp["drifts"], [], cmp)
        self.assertEqual(cmp["shape_failures"], [], cmp)

    def test_wall_shape_collapse_fails(self):
        cur = report([sweep(walls=(1.0, 0.55, 1.1))])
        set_series(cur["sweeps"][0], "wall_speedup", [1.0, 1.8, 0.9])
        cmp = gate_curve.compare_curves(cur, report([sweep()]), 8)
        self.assertTrue(any("wall_speedup" in s for s in cmp["shape_failures"]), cmp)

    def test_missing_and_new_sweeps(self):
        cmp = gate_curve.compare_curves(report([]), report([sweep()]), 8)
        self.assertEqual(cmp["missing"], ["workers"])
        cmp = gate_curve.compare_curves(report([sweep()]), report([]), 8)
        self.assertEqual(cmp["unchecked"], ["workers"])
        self.assertEqual(cmp["missing"], [])

    def test_virtual_series_disappearing_is_drift(self):
        cur = report([sweep()])
        cur["sweeps"][0]["series"] = [
            s for s in cur["sweeps"][0]["series"] if s["name"] != "virtual_s"
        ]
        cmp = gate_curve.compare_curves(cur, report([sweep()]), 8)
        self.assertTrue(any("disappeared" in d for d in cmp["drifts"]), cmp)


class TestCheckRequired(unittest.TestCase):
    def test_present_with_gates_passes(self):
        sw = sweep()
        self.assertEqual(gate_curve.check_required(report([sw]), ["workers"]), [])

    def test_missing_sweep_fails(self):
        fails = gate_curve.check_required(report([]), ["workers"])
        self.assertTrue(any("missing" in f for f in fails), fails)

    def test_defanged_gates_fail(self):
        sw = sweep()
        sw["gates"] = [g for g in sw["gates"] if g["kind"] != "knee"]
        fails = gate_curve.check_required(report([sw]), ["workers"])
        self.assertTrue(any("knee" in f for f in fails), fails)
        sw["gates"] = []
        fails = gate_curve.check_required(report([sw]), ["workers"])
        self.assertEqual(len(fails), 2, fails)


def wordcount_report(reduces=2.4e6, pairs=1.2e6, par=0.8, seq=2.0):
    return {
        "schema": "cloud2sim-bench/2",
        "scenarios": [{
            "name": "megascale_wordcount",
            "pairs_per_sec": pairs,
            "extras": {"reduce_invocations": reduces},
            "wall_extras": {"wall_parallel_s": par, "wall_sequential_s": seq},
        }],
    }


class TestWordcountGate(unittest.TestCase):
    def test_passing_report(self):
        lines, failures = gate_wordcount.check_wordcount(wordcount_report())
        self.assertEqual(failures, [])
        self.assertTrue(any("speedup" in l for l in lines), lines)

    def test_floor_and_win_failures(self):
        _, f = gate_wordcount.check_wordcount(wordcount_report(reduces=1e6))
        self.assertTrue(any("2M" in x for x in f), f)
        _, f = gate_wordcount.check_wordcount(wordcount_report(pairs=None))
        self.assertTrue(any("pairs_per_sec" in x for x in f), f)
        _, f = gate_wordcount.check_wordcount(wordcount_report(par=2.5, seq=2.0))
        self.assertTrue(any("beat the sequential" in x for x in f), f)

    def test_missing_scenario(self):
        _, f = gate_wordcount.check_wordcount({"scenarios": []})
        self.assertTrue(any("missing" in x for x in f), f)


def multitenant_report(cloudlets=1_000_000.0, tenants=4.0, bytes_per=0.9,
                       spread=1.02, starved=None):
    extras = {
        "cloudlets_ok": cloudlets,
        "tenants": tenants,
        "bytes_per_cloudlet": bytes_per,
        "p99_spread_ratio": spread,
    }
    for t in range(int(tenants)):
        extras[f"tenant_{t}_completed"] = 0.0 if t == starved else cloudlets / tenants
    return {
        "schema": "cloud2sim-bench/2",
        "scenarios": [{"name": "megascale_multitenant", "extras": extras}],
    }


class TestMultitenantGate(unittest.TestCase):
    def test_passing_report(self):
        lines, failures = gate_multitenant.check_multitenant(multitenant_report())
        self.assertEqual(failures, [])
        self.assertTrue(any("bytes/cloudlet" in l for l in lines), lines)

    def test_megascale_and_tenancy_floors(self):
        _, f = gate_multitenant.check_multitenant(multitenant_report(cloudlets=5e5))
        self.assertTrue(any("megascale floor" in x for x in f), f)
        _, f = gate_multitenant.check_multitenant(multitenant_report(tenants=2.0))
        self.assertTrue(any("tenancy floor" in x for x in f), f)

    def test_memory_budget(self):
        _, f = gate_multitenant.check_multitenant(multitenant_report(bytes_per=56.0))
        self.assertTrue(any("memory budget" in x for x in f), f)
        _, f = gate_multitenant.check_multitenant(multitenant_report(bytes_per=None))
        self.assertTrue(any("bytes_per_cloudlet" in x for x in f), f)

    def test_fairness_spread(self):
        _, f = gate_multitenant.check_multitenant(multitenant_report(spread=1.8))
        self.assertTrue(any("fairness broken" in x for x in f), f)
        _, f = gate_multitenant.check_multitenant(multitenant_report(spread=0.4))
        self.assertTrue(any("p99_spread_ratio" in x for x in f), f)

    def test_starved_tenant_fails(self):
        _, f = gate_multitenant.check_multitenant(multitenant_report(starved=2))
        self.assertTrue(any("starved" in x for x in f), f)

    def test_missing_scenario(self):
        _, f = gate_multitenant.check_multitenant({"scenarios": []})
        self.assertTrue(any("missing" in x for x in f), f)


def fault_reports(crashes=2.0, wins=3.0, lost=0.0):
    churn = {
        "scenarios": [{
            "name": "member_churn_elastic",
            "extras": {
                "crashes": crashes, "rejoins": crashes, "tasks_reexecuted": 5.0,
                "entries_migrated": 100.0, "entries_lost": lost,
                "cloudlets_ok": 400.0, "churn_virtual_overhead_s": 1.25,
            },
            "scale_events": (
                [{"at": 10.0, "action": "crash", "instances_after": 2},
                 {"at": 20.0, "action": "rejoin", "instances_after": 3}]
                if crashes else []
            ),
        }],
    }
    straggler = {
        "scenarios": [{
            "name": "mr_straggler_speculative",
            "extras": {"speculative_wins": wins, "fault_events": wins},
        }],
    }
    return churn, straggler


def failover_report(dc_crashes=1.0, rebound=215.0, fingerprint=7.3e12,
                    ok=999_785.0, failed=215.0, tenants=4.0, starved=None,
                    with_events=True):
    extras = {
        "dc_crashes": dc_crashes, "dc_recovers": dc_crashes,
        "rebound": rebound, "retries_exhausted": 0.0,
        "fault_fingerprint": fingerprint,
        "cloudlets_ok": ok, "cloudlets_failed": failed,
        "tenants": tenants,
    }
    for t in range(int(tenants)):
        extras[f"tenant_{t}_completed"] = 0.0 if t == starved else ok / tenants
    return {
        "schema": "cloud2sim-bench/2",
        "scenarios": [{
            "name": "megascale_dc_failover",
            "extras": extras,
            "scale_events": (
                [{"at": 300.0, "action": "dc-crash", "instances_after": 2},
                 {"at": 900.0, "action": "dc-recover", "instances_after": 2}]
                if with_events else []
            ),
        }],
    }


class TestFaultGate(unittest.TestCase):
    def test_passing_reports(self):
        churn, straggler = fault_reports()
        lines, failures, doc = gate_faults.check_faults(churn, straggler)
        self.assertEqual(failures, [])
        self.assertIn("member_churn_elastic", doc)
        self.assertEqual(len(doc["member_churn_elastic"]["scale_events"]), 2)
        self.assertIn("mr_straggler_speculative", doc)

    def test_defanged_plan_fails(self):
        churn, straggler = fault_reports(crashes=0.0, wins=0.0)
        _, failures, _ = gate_faults.check_faults(churn, straggler)
        self.assertTrue(any("crash" in f for f in failures), failures)
        self.assertTrue(any("straggler" in f for f in failures), failures)

    def test_lost_entries_fail(self):
        churn, straggler = fault_reports(lost=3.0)
        _, failures, _ = gate_faults.check_faults(churn, straggler)
        self.assertTrue(any("lose" in f for f in failures), failures)

    def test_failover_passing_report(self):
        churn, straggler = fault_reports()
        lines, failures, doc = gate_faults.check_faults(
            churn, straggler, failover_report()
        )
        self.assertEqual(failures, [])
        self.assertIn("megascale_dc_failover", doc)
        self.assertEqual(len(doc["megascale_dc_failover"]["scale_events"]), 2)
        self.assertTrue(any("rebound" in l for l in lines), lines)

    def test_failover_defanged_plan_fails(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, failover_report(dc_crashes=0.0, with_events=False)
        )
        self.assertTrue(any("never crashed" in f for f in failures), failures)
        self.assertTrue(
            any("dc-crash/dc-recover missing" in f for f in failures), failures
        )

    def test_failover_no_rebind_fails(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, failover_report(rebound=0.0)
        )
        self.assertTrue(any("re-bind" in f for f in failures), failures)

    def test_failover_starved_tenant_fails(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, failover_report(starved=2)
        )
        self.assertTrue(any("starved" in f for f in failures), failures)

    def test_failover_unbounded_failures_fail(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, failover_report(ok=100.0, failed=5_000.0)
        )
        self.assertTrue(any("unbounded" in f for f in failures), failures)

    def test_failover_missing_fingerprint_fails(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, failover_report(fingerprint=0.0)
        )
        self.assertTrue(any("fingerprint" in f for f in failures), failures)

    def test_failover_missing_scenario(self):
        churn, straggler = fault_reports()
        _, failures, _ = gate_faults.check_faults(
            churn, straggler, {"scenarios": []}
        )
        self.assertTrue(any("missing" in f for f in failures), failures)


def partition_report(retries=42.0, dedup=7.0, dropped=31.0, merges=1.0,
                     fingerprint=8.1e12, overhead=3.5, virtual_s=17.25,
                     with_events=True):
    actions = ["link-partition", "split-brain", "link-heal", "split-brain-merge"]
    return {
        "schema": "cloud2sim-bench/2",
        "scenarios": [{
            "name": "mr_partition_splitbrain",
            "virtual_s": virtual_s,
            "extras": {
                "net_messages": 1200.0, "net_bytes": 4.2e6,
                "net_retries": retries, "net_dropped": dropped,
                "net_deduplicated": dedup, "split_brain_merges": merges,
                "fault_fingerprint": fingerprint, "fault_events": 60.0,
                "sim_time_nofault_s": virtual_s - overhead,
                "partition_virtual_overhead_s": overhead,
                "reduce_invocations": 900.0, "emitted_pairs": 48_000.0,
            },
            "scale_events": (
                [{"at": 0.001 + i, "action": a, "instances_after": 2}
                 for i, a in enumerate(actions)]
                if with_events else []
            ),
        }],
    }


class TestPartitionGate(unittest.TestCase):
    def test_passing_report(self):
        lines, failures, doc = gate_partition.check_partition(partition_report())
        self.assertEqual(failures, [])
        self.assertIn("mr_partition_splitbrain", doc)
        self.assertEqual(len(doc["mr_partition_splitbrain"]["scale_events"]), 4)
        self.assertTrue(any("net_retries" in l for l in lines), lines)

    def test_defanged_links_fail(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(retries=0.0, dedup=0.0, dropped=0.0)
        )
        self.assertTrue(any("retry" in f for f in failures), failures)
        self.assertTrue(any("dedup" in f for f in failures), failures)
        self.assertTrue(any("dropped" in f for f in failures), failures)

    def test_missing_merge_fails(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(merges=0.0, with_events=False)
        )
        self.assertTrue(any("merge" in f for f in failures), failures)
        self.assertTrue(
            any("link-partition missing" in f for f in failures), failures
        )

    def test_missing_fingerprint_fails(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(fingerprint=0.0)
        )
        self.assertTrue(any("fingerprint" in f for f in failures), failures)

    def test_negative_overhead_fails(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(overhead=-0.5)
        )
        self.assertTrue(any("faster" in f for f in failures), failures)

    def test_rerun_agreement_passes(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(), partition_report()
        )
        self.assertEqual(failures, [])

    def test_rerun_drift_fails(self):
        _, failures, _ = gate_partition.check_partition(
            partition_report(), partition_report(virtual_s=17.26)
        )
        self.assertTrue(any("drifted between runs" in f for f in failures), failures)
        _, failures, _ = gate_partition.check_partition(
            partition_report(), partition_report(retries=43.0)
        )
        self.assertTrue(
            any("net_retries drifted" in f for f in failures), failures
        )

    def test_missing_scenario(self):
        _, failures, _ = gate_partition.check_partition({"scenarios": []})
        self.assertTrue(any("missing" in f for f in failures), failures)


if __name__ == "__main__":
    unittest.main(verbosity=2)
