#!/usr/bin/env python3
"""Gate the megascale multi-tenant scenario's memory and fairness floors.

Reads a ``cloud2sim-bench/2`` report (``BENCH_multitenant.json``) and
re-asserts what makes the scenario megascale and multi-tenant: at least
1M cloudlets completed across at least 4 concurrent tenant brokers, the
streaming store's modeled peak heap within a per-submitted-cloudlet byte
budget (memory must scale with *active* work, not with everything ever
submitted), and per-tenant p99 turnaround spread within a fairness bound
(symmetric tenants must see symmetric service).

The pure core :func:`check_multitenant` takes the parsed report and
returns ``(lines, failures)`` — printable evidence and failure strings —
so ``ci/test_gates.py`` can unit-test the gate logic without touching
disk.
"""

import argparse
import json
import sys

# megascale floors (mirrors rust/src/scenarios/runner.rs expectations)
MIN_CLOUDLETS = 1_000_000
MIN_TENANTS = 4
# streaming-store budget: modeled peak heap per *submitted* cloudlet. The
# retained seed path costs 56 bytes/cloudlet by construction; streaming
# mode holds the whole pipeline more than an order of magnitude under it.
MAX_BYTES_PER_CLOUDLET = 16.0
# per-tenant p99 turnaround spread (max/min) for symmetric tenants
MAX_P99_SPREAD = 1.5


def check_multitenant(report):
    """Pure gate core: parsed report -> (printable lines, failures)."""
    lines, failures = [], []
    matches = [
        s
        for s in report.get("scenarios", [])
        if s.get("name") == "megascale_multitenant"
    ]
    if not matches:
        return lines, ["megascale_multitenant missing from the report"]
    sc = matches[0]
    extras = sc.get("extras", {})
    cloudlets = extras.get("cloudlets_ok")
    tenants = extras.get("tenants")
    bytes_per = extras.get("bytes_per_cloudlet")
    spread = extras.get("p99_spread_ratio")

    if cloudlets is not None:
        lines.append(f"cloudlets completed : {cloudlets:.0f}")
    if tenants is not None:
        lines.append(f"tenants             : {tenants:.0f}")
    if bytes_per is not None:
        lines.append(f"bytes/cloudlet      : {bytes_per:.2f} (budget {MAX_BYTES_PER_CLOUDLET:.0f})")
    if spread is not None:
        lines.append(f"p99 spread          : {spread:.3f}x (bound {MAX_P99_SPREAD}x)")

    if cloudlets is None or cloudlets < MIN_CLOUDLETS:
        failures.append(f"megascale floor broken: need >= {MIN_CLOUDLETS} cloudlets completed")
    if tenants is None or tenants < MIN_TENANTS:
        failures.append(f"tenancy floor broken: need >= {MIN_TENANTS} concurrent tenants")
    if bytes_per is None or not bytes_per > 0:
        failures.append("bytes_per_cloudlet missing or non-positive")
    elif bytes_per > MAX_BYTES_PER_CLOUDLET:
        failures.append(
            f"memory budget broken: {bytes_per:.2f} bytes/cloudlet "
            f"> {MAX_BYTES_PER_CLOUDLET} (peak heap must track active VMs, not submissions)"
        )
    if spread is None or not spread >= 1.0:
        failures.append("p99_spread_ratio missing or < 1 (max/min must be >= 1)")
    elif spread > MAX_P99_SPREAD:
        failures.append(
            f"fairness broken: per-tenant p99 spread {spread:.3f}x > {MAX_P99_SPREAD}x"
        )
    # every tenant must have actually completed work
    per_tenant = sorted(
        (k, v) for k, v in extras.items() if k.startswith("tenant_") and k.endswith("_completed")
    )
    if tenants is not None and len(per_tenant) < int(tenants):
        failures.append("per-tenant completion extras missing")
    for key, done in per_tenant:
        lines.append(f"{key:<20}: {done:.0f}")
        if not done > 0:
            failures.append(f"{key} is zero — a tenant was starved")
    return lines, failures


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "report",
        nargs="?",
        default="BENCH_multitenant.json",
        help="bench report to gate (default: %(default)s)",
    )
    args = p.parse_args(argv)
    with open(args.report) as f:
        report = json.load(f)
    lines, failures = check_multitenant(report)
    for line in lines:
        print(line)
    for failure in failures:
        print(f"FAIL {failure}", file=sys.stderr)
    if failures:
        return 1
    print("multitenant gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
