//! Elastic middleware demo: adaptive scaling of a loaded simulation
//! (Algorithms 4–6), multi-tenant coordination (Fig 3.4), and IaaS cost
//! accounting (Fig 3.5).
//!
//! ```sh
//! cargo run --release --example elastic_scaling
//! ```

use cloud2sim::elastic::{
    run_adaptive, CloudProvisioner, Coordinator, HealthMeasure, SimEc2,
};
use cloud2sim::metrics::Table;
use cloud2sim::prelude::*;
use cloud2sim::runtime::workload::NativeBurnModel;

fn main() -> Result<()> {
    println!("Cloud2Sim — elastic middleware platform\n");

    // ---- adaptive scaling of a loaded simulation ----
    let cfg = SimConfig {
        backup_count: 1, // elastic runs require synchronous backups (§3.4.3)
        max_threshold: 0.20,
        min_threshold: 0.01,
        time_between_scaling: 40.0,
        ..SimConfig::default_round_robin(200, 400, true)
    };
    let mut model = NativeBurnModel::default();
    let report = run_adaptive(&cfg, 5, HealthMeasure::LoadAverage, &mut model)?;

    let mut t = Table::new(
        "Adaptive scaling events (Table 5.2 style)",
        &["t (s)", "instances", "loads", "event"],
    );
    for row in report.rows.iter().filter(|r| !r.event.starts_with("Health") ) {
        t.row(&[
            format!("{:.0}", row.at),
            row.instances.to_string(),
            row.loads
                .iter()
                .map(|l| format!("{l:.2}"))
                .collect::<Vec<_>>()
                .join(" "),
            row.event.clone(),
        ]);
    }
    t.print();
    println!(
        "\nadaptive run: {:.1}s, peak {} instances, {} scale-outs, {} cloudlets",
        report.sim_time_s, report.peak_instances, report.scale_outs, report.cloudlets_ok
    );

    // ---- the same elasticity priced on a simulated IaaS (Fig 3.5) ----
    let mut ec2 = SimEc2::new();
    let mut ready = Vec::new();
    for _ in 0..report.scale_outs {
        ready.push(ec2.provision(0.0));
    }
    for _ in 0..report.scale_outs {
        ec2.release(report.sim_time_s);
    }
    println!(
        "on {}: {} instances provisioned (boot latency {:.0}s each), cost ${:.2}",
        ec2.name(),
        ec2.total_provisioned(),
        ec2.spawn_latency,
        ec2.cost(report.sim_time_s)
    );

    // ---- multi-tenant coordination (Fig 3.4) ----
    let mut coord = Coordinator::new();
    coord.add_tenant("exp1", SimConfig::default_round_robin(100, 200, true), 2);
    coord.add_tenant("exp2", SimConfig::default_round_robin(50, 100, false), 3);
    coord.add_tenant("exp3", SimConfig::default_round_robin(80, 160, true), 2);
    coord.run_all()?;
    print!("{}", coord.deployment_matrix());
    print!("{}", coord.combined_report());
    println!(
        "\nmulti-tenant makespan (parallel tenants): {:.1}s",
        coord.makespan()
    );
    Ok(())
}
