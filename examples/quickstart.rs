//! Quickstart: run a round-robin scheduling simulation on plain CloudSim
//! and on Cloud²Sim over 1 and 4 simulated nodes, and inspect the grid's
//! storage distribution.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cloud2sim::dist::{run_cloudsim_baseline, run_distributed};
use cloud2sim::metrics::Table;
use cloud2sim::prelude::*;

fn main() -> Result<()> {
    println!("Cloud2Sim quickstart — round-robin application scheduling\n");

    // 100 VMs, 200 loaded cloudlets (the paper's success case B)
    let cfg = SimConfig::default_round_robin(100, 200, true);

    let base = run_cloudsim_baseline(&cfg)?;
    println!(
        "CloudSim (single JVM):       {:>8.2}s  ({} cloudlets, {} DES events)",
        base.sim_time_s, base.cloudlets_ok, base.events
    );

    let one = run_distributed(&cfg, 1)?;
    println!(
        "Cloud2Sim, 1 instance:       {:>8.2}s  (grid overhead visible)",
        one.sim_time_s
    );

    let four = run_distributed(&cfg, 4)?;
    println!(
        "Cloud2Sim, 4 instances:      {:>8.2}s  (speedup {:.1}x vs 1 instance)",
        four.sim_time_s,
        one.sim_time_s / four.sim_time_s
    );

    let mut t = Table::new(
        "Distributed cloudlet storage across 4 instances (Fig 5.8 view)",
        &["member", "entries", "bytes"],
    );
    for (i, (entries, bytes)) in four.distribution.iter().enumerate() {
        t.row(&[
            format!("member-{i}"),
            entries.to_string(),
            bytes.to_string(),
        ]);
    }
    t.print();

    println!(
        "\ngrid traffic: {} messages, {} payload bytes",
        four.grid_messages, four.grid_bytes
    );
    println!("done.");
    Ok(())
}
