//! Fair matchmaking-based cloudlet scheduling (§5.1.2), with the scoring
//! hot loop executed by the AOT-compiled Pallas kernel via PJRT when
//! `artifacts/` has been built (`make artifacts`), falling back to the
//! native Rust scorer otherwise.
//!
//! ```sh
//! make artifacts && cargo run --release --example matchmaking
//! ```

use cloud2sim::dist::matchmaking::{
    matchmake_native, required_size, run_matchmaking_baseline, run_matchmaking_distributed,
};
use cloud2sim::metrics::Table;
use cloud2sim::prelude::*;
use cloud2sim::runtime::registry::{default_artifacts_dir, PjrtRuntime};

fn main() -> Result<()> {
    println!("Cloud2Sim — fair matchmaking-based scheduling\n");
    let cfg = SimConfig {
        no_of_vms: 100,
        no_of_cloudlets: 1200,
        ..SimConfig::default()
    };

    // PJRT runtime, if artifacts exist
    let mut pjrt = match PjrtRuntime::load(default_artifacts_dir()) {
        Ok(rt) => {
            println!("PJRT ready on '{}', artifacts: {}", rt.platform(), rt.manifest.len());
            Some(rt)
        }
        Err(e) => {
            println!("(no PJRT artifacts — native scoring only: {e})");
            None
        }
    };

    // kernel-vs-native parity spot check
    if let Some(rt) = pjrt.as_mut() {
        let entry = rt.pick_matchmake(256, 64)?;
        let reqs: Vec<f32> = (0..entry.d1).map(|i| 10.0 + (i % 37) as f32).collect();
        let caps: Vec<f32> = (0..entry.d2).map(|v| 8.0 + (v % 53) as f32 * 1.7).collect();
        let loads: Vec<f32> = (0..entry.d2).map(|v| (v % 5) as f32).collect();
        let (k_assign, k_best, wall) = rt.execute_matchmake(&entry, &reqs, &caps, &loads)?;
        let (n_assign, n_best) = matchmake_native(&reqs, &caps, &loads);
        assert_eq!(k_assign, n_assign, "kernel and native must agree on bindings");
        for (a, b) in k_best.iter().zip(n_best.iter()) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
        println!(
            "kernel parity OK: {} cloudlets x {} VMs scored in {:?} (assignments identical)\n",
            entry.d1, entry.d2, wall
        );
    }

    // the paper's scaling sweep
    let base = run_matchmaking_baseline(&cfg)?;
    let mut table = Table::new(
        "Matchmaking simulation time (1200 cloudlets, 100 VMs)",
        &["deployment", "time (s)", "speedup", "max CPU load"],
    );
    table.row(&[
        "CloudSim".into(),
        format!("{:.1}", base.sim_time_s),
        "1.0x".into(),
        "1.00".into(),
    ]);
    for n in [1usize, 2, 3, 4, 6] {
        let r = run_matchmaking_distributed(&cfg, n, pjrt.as_mut())?;
        table.row(&[
            format!("Cloud2Sim ({n})"),
            format!("{:.1}", r.sim_time_s),
            format!("{:.1}x", base.sim_time_s / r.sim_time_s),
            format!("{:.2}", r.max_process_cpu_load),
        ]);
    }
    table.print();

    let example_req = required_size(40_000);
    println!("\n(cloudlet of 40,000 MI requires a VM of size ≥ {example_req})");
    if let Some(rt) = pjrt.as_ref() {
        println!(
            "PJRT kernel executions: {} ({:?} total)",
            rt.total_executions(),
            rt.total_kernel_time()
        );
    }
    Ok(())
}
