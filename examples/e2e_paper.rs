//! END-TO-END driver (recorded in EXPERIMENTS.md): the full three-layer
//! system on the paper's headline workload.
//!
//! * L1/L2: the AOT-compiled Pallas `cloudlet_burn` kernel really executes
//!   on the hot path of every workload round (PJRT, CPU client), and the
//!   `matchmake` kernel scores the matchmaking scenario.
//! * L3: the Rust coordinator runs the Table 5.1 scenario — 200 VMs,
//!   400 loaded cloudlets, 15 datacenters, round-robin scheduling — on
//!   plain CloudSim and on Cloud²Sim over 1/2/3/6 simulated nodes.
//!
//! Requires `make artifacts`; falls back to the calibrated native model
//! (with a warning) when artifacts are missing.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_paper
//! ```

use cloud2sim::dist::{run_cloudsim_baseline_with, run_distributed_full, Strategy};
use cloud2sim::dist::matchmaking::run_matchmaking_distributed;
use cloud2sim::metrics::Table;
use cloud2sim::prelude::*;
use cloud2sim::runtime::registry::{default_artifacts_dir, PjrtRuntime};
use cloud2sim::runtime::workload::{NativeBurnModel, PjrtBurnModel, WorkloadModel};

fn main() -> Result<()> {
    println!("Cloud2Sim end-to-end driver — Table 5.1 with the PJRT kernel on the hot path\n");

    let dir = default_artifacts_dir();
    let mut model: Box<dyn WorkloadModel> = match PjrtRuntime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for e in &rt.manifest {
                println!("  artifact {:?} {} ({}x{} t={})", e.kind, e.name, e.d1, e.d2, e.d3);
            }
            Box::new(PjrtBurnModel::new(rt, 256)?)
        }
        Err(e) => {
            println!("WARNING: {e}\n         running with the native workload model instead.");
            Box::new(NativeBurnModel::default())
        }
    };
    println!();

    let cfg = SimConfig::default_round_robin(200, 400, true);
    let paper = ["1247.400", "1259.743", "120.009", "96.053", "104.440"];

    let mut table = Table::new(
        "Table 5.1 (loaded) — paper vs measured, real kernel executions",
        &["deployment", "measured (s)", "paper (s)", "kernel wall", "speedup vs serial"],
    );

    let base = run_cloudsim_baseline_with(&cfg, model.as_mut(), true)?;
    table.row(&[
        "CloudSim".into(),
        format!("{:.1}", base.sim_time_s),
        paper[0].into(),
        format!("{:?}", base.workload_wall),
        "1.0x".into(),
    ]);

    let mut measured = vec![base.sim_time_s];
    for (i, n) in [1usize, 2, 3, 6].iter().enumerate() {
        let r = run_distributed_full(&cfg, *n, Strategy::MultipleSimulator, model.as_mut(), true)?;
        table.row(&[
            format!("Cloud2Sim ({n} node{})", if *n > 1 { "s" } else { "" }),
            format!("{:.1}", r.sim_time_s),
            paper[i + 1].into(),
            format!("{:?}", r.workload_wall),
            format!("{:.1}x", base.sim_time_s / r.sim_time_s),
        ]);
        measured.push(r.sim_time_s);
    }
    table.print();

    // headline shape assertions
    let (t1, t2, t3, t6) = (measured[1], measured[2], measured[3], measured[4]);
    assert!(t1 / t2 > 5.0, "~10x improvement at 2 nodes: {t1} -> {t2}");
    assert!(t3 < t2 && t6 > t3, "3-node optimum with 6-node coordination cost");
    println!(
        "\nheadline: {:.1}x speedup at 2 nodes, {:.1}x at 3 nodes (paper: 10.4x / 13.0x)",
        t1 / t2,
        t1 / t3
    );

    // matchmaking with the real kernel
    if let Ok(rt) = PjrtRuntime::load(&dir) {
        let mut rt = rt;
        let mcfg = SimConfig {
            no_of_vms: 100,
            no_of_cloudlets: 1200,
            ..SimConfig::default()
        };
        let r1 = run_matchmaking_distributed(&mcfg, 1, Some(&mut rt))?;
        let r3 = run_matchmaking_distributed(&mcfg, 3, Some(&mut rt))?;
        println!(
            "\nmatchmaking (PJRT scored): 1 node {:.1}s -> 3 nodes {:.1}s ({:.1}x), kernel wall {:?}",
            r1.sim_time_s,
            r3.sim_time_s,
            r1.sim_time_s / r3.sim_time_s,
            r1.workload_wall + r3.workload_wall,
        );
        println!(
            "PJRT totals: {} kernel executions, {:?} in-kernel wall time",
            rt.total_executions(),
            rt.total_kernel_time()
        );
    }
    println!("\ne2e OK — all layers composed (L1 Pallas kernel → L2 HLO artifact → L3 coordinator).");
    Ok(())
}
