//! MapReduce word count on both grid backends (§4.2): same job, same
//! corpus, Hazelcast-profile vs Infinispan-profile — reproducing the
//! paper's comparative setup in miniature.
//!
//! ```sh
//! cargo run --release --example mapreduce_wordcount
//! ```

use cloud2sim::mapreduce::{run_hz_wordcount, run_inf_wordcount, Corpus, CorpusConfig, JobConfig};
use cloud2sim::metrics::Table;
use cloud2sim::prelude::*;

fn main() -> Result<()> {
    println!("Cloud2Sim — MapReduce word count (both backends)\n");
    let heap = 64 * 1024 * 1024;
    let corpus = || {
        Corpus::new(CorpusConfig {
            files: 3,
            distinct_files: 3,
            lines_per_file: 5_000,
            ..CorpusConfig::default()
        })
    };

    let mut table = Table::new(
        "Word count: 3 files x 5000 lines",
        &["backend", "instances", "map()", "reduce()", "time (s)", "conserved"],
    );
    let mut last_top = None;
    for instances in [1usize, 2, 4] {
        let hz = run_hz_wordcount(corpus(), JobConfig::default(), instances, heap)?;
        table.row(&[
            "hazelcast".into(),
            instances.to_string(),
            hz.map_invocations.to_string(),
            hz.reduce_invocations.to_string(),
            format!("{:.2}", hz.sim_time_s),
            hz.is_conserved().to_string(),
        ]);
        let inf = run_inf_wordcount(corpus(), JobConfig::default(), instances, heap)?;
        table.row(&[
            "infinispan".into(),
            instances.to_string(),
            inf.map_invocations.to_string(),
            inf.reduce_invocations.to_string(),
            format!("{:.2}", inf.sim_time_s),
            inf.is_conserved().to_string(),
        ]);
        assert_eq!(
            hz.top_words, inf.top_words,
            "identical job ⇒ identical output on both backends"
        );
        last_top = Some(inf.top_words);
    }
    table.print();

    if let Some(top) = last_top {
        let mut t = Table::new("Top words (identical on every run)", &["word", "count"]);
        for (w, c) in top.iter().take(5) {
            t.row(&[w.clone(), c.to_string()]);
        }
        t.print();
    }
    println!("\ndone — results identical across backends and cluster sizes.");
    Ok(())
}
